"""Chunked continuous-batching prefill: query-block kernel vs oracle, the
mid-page chunk writer, bit-identical logits across chunk splits, bounded step
times + TTFT-under-burst regression, restore-prefetch overlap, the scheduling
invariant error, and the jit-retrace guard (trace count flat across a
mixed-length workload — wired into the tier-1 CI workflow).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import HOST, REMOTE
from repro.kernels.paged_attention.kernel import paged_prefill_attention_pool
from repro.kernels.paged_attention.ref import \
    paged_prefill_attention_pool_ref
from repro.layers.attention import write_chunk_pages
from repro.models import api, lm
from repro.serving.engine import SchedulingInvariantError, ServingEngine
from repro.serving.kv_cache import PagedStateRuntime
from repro.serving.scheduler import (Decision, bucket_tokens,
                                     split_step_budget)

ARCH = "qwen1.5-0.5b"


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


def _greedy(cfg, params, prompt, n, max_seq=64):
    cache = api.init_decode_state(cfg, 1, max_seq)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = api.prefill(params, cfg, toks, cache)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        pos = jnp.asarray([len(prompt) + len(out) - 1], jnp.int32)
        logits, cache = api.decode_step(params, cfg, cache,
                                        jnp.asarray([out[-1]], jnp.int32), pos)
        out.append(int(jnp.argmax(logits[0])))
    return out


# ---------------------------------------------------------------------------
# kernel: query-block fused-pool variant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_kernel_matches_ref(dtype):
    rng = np.random.default_rng(0)
    B, Tc, H, K, hd, P, page, pps = 2, 6, 4, 2, 32, 16, 8, 4
    q = _rand(rng, (B, Tc, H, hd), dtype)
    pool = _rand(rng, (P, 2, K, page, hd), dtype)
    bt = jnp.asarray(rng.integers(0, P, (B, pps)), jnp.int32)
    starts = jnp.asarray([3, 10], jnp.int32)          # mid-page chunk starts
    out = paged_prefill_attention_pool(q, pool, bt, starts, interpret=True)
    ref = paged_prefill_attention_pool_ref(q, pool, bt, starts)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_write_chunk_pages_mid_page_boundary_preserves_earlier_rows():
    """Chunked writes (incl. chunk boundaries mid-page) produce the same
    pages as one whole-prompt write: the read-modify-write window must not
    clobber rows written by earlier chunks."""
    rng = np.random.default_rng(1)
    K, hd, page, pps = 2, 16, 8, 3
    S = page * pps                                    # 24 tokens
    k = _rand(rng, (1, S, K, hd), jnp.float32)
    v = _rand(rng, (1, S, K, hd), jnp.float32)
    bt = jnp.asarray([1, 2, 3], jnp.int32)            # slot 0 = scratch
    bt_pad = jnp.concatenate([bt, jnp.zeros((4,), jnp.int32)])

    def write(splits):
        pool = jnp.zeros((pps + 1, 2, K, page, hd), jnp.float32)
        pos = 0
        for c in splits:
            start_page = pos // page
            w = c // page + (1 if c % page else 0) + 1
            win = jax.lax.dynamic_slice(bt_pad, (start_page,), (w,))
            pool = write_chunk_pages(pool, k[:, pos:pos + c],
                                     v[:, pos:pos + c], win,
                                     jnp.int32(pos % page), page_tokens=page)
            pos += c
        return pool

    whole = write([S])
    for splits in ([5, 7, 12], [8, 8, 8], [3, 21], [13, 11]):
        chunked = write(splits)
        np.testing.assert_array_equal(np.asarray(chunked[bt]),
                                      np.asarray(whole[bt]))


# ---------------------------------------------------------------------------
# budget splitting + shape buckets
# ---------------------------------------------------------------------------
def test_split_step_budget_fair_shares_across_pending_prefills():
    # a short prompt's chunk rides the same step as the long prefill
    assert split_step_budget(16, 0, [64, 6]) == [10, 6]
    assert split_step_budget(16, 4, [64, 6]) == [6, 6]
    assert split_step_budget(16, 0, [64]) == [16]
    # lanes ate the budget: the progress floor still grants one token, so an
    # admitted prefill can never starve behind a saturated decode batch
    assert split_step_budget(8, 8, [64]) == [1]
    assert split_step_budget(8, 8, []) == []
    assert split_step_budget(None, 2, [64, 6]) == [64, 6]   # unchunked
    assert sum(split_step_budget(16, 1, [5, 5, 5, 5])) <= 15


def test_bucket_tokens_ladder():
    assert [bucket_tokens(n) for n in (1, 8, 9, 13, 16, 17, 40)] == \
        [8, 8, 16, 16, 16, 32, 64]


# ---------------------------------------------------------------------------
# chunked prefill parity: bit-identical logits for ANY chunk split
# ---------------------------------------------------------------------------
def test_chunked_prefill_bit_identical_across_chunk_sizes():
    """Whole-prompt prefill is the single-chunk case; every split — including
    chunk boundaries mid-page — yields BIT-identical logits, because each
    token's page-sequence softmax reduction order is split-invariant."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 17)))
    pad_to = 16                                       # pps(8)+spill, page=8

    def last_logits(splits):
        kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
        pos = 0
        out = None
        for c in splits:
            kv.ensure_capacity(0, pos + c)
            bt = kv.block_tables_prefill(0, pad_to=pad_to)
            toks = jnp.asarray(prompt[pos:pos + c], jnp.int32)[None]
            logits, kv.pools = lm.prefill_chunk_paged(
                params, cfg, toks, kv.pools, bt, jnp.int32(pos),
                jnp.int32(c - 1))
            pos += c
            out = logits[0]
        return np.asarray(out)

    whole = last_logits([17])
    for splits in ([5, 12], [8, 4, 5], [12, 5], [16, 1]):
        np.testing.assert_array_equal(last_logits(splits), whole), splits


def test_engine_chunked_tokens_match_greedy_incl_mid_page_chunks():
    """End-to-end through the engine with a budget that forces multi-chunk,
    mid-page-boundary prefill (13 % 8 != 0): tokens == direct greedy."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (19, 11, 26)]
    truth = [_greedy(cfg, params, p, 4) for p in prompts]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST,
                        step_tokens=13)
    for p in prompts:
        eng.submit(p, 4)
    m = eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    # the budget really chunked the prefills: more chunk executions than
    # requests, and no step ever prefilled more than step_tokens tokens
    assert m.prefills > len(prompts)


# ---------------------------------------------------------------------------
# bounded step times + TTFT under burst (the headline regression)
# ---------------------------------------------------------------------------
def _burst_engine(cfg, params, long_len, step_tokens, rng_seed=4):
    rng = np.random.default_rng(rng_seed)
    long_p = list(map(int, rng.integers(0, cfg.vocab_size, long_len)))
    shorts = [list(map(int, rng.integers(0, cfg.vocab_size, 6)))
              for _ in range(3)]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST,
                        step_tokens=step_tokens, prefetch=False)
    eng.submit(long_p, 3, arrival=0.0)                # the head-of-line hog
    for s in shorts:
        eng.submit(s, 3, arrival=0.0)
    m = eng.run(400)
    short_ttfts = [m.ttft[r.rid] for r in eng.finished
                   if len(r.prompt_tokens) == 6]
    assert len(short_ttfts) == 3
    return m, short_ttfts


def test_engine_bounded_step_tokens_and_first_token_under_burst():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    m_whole, ttft_whole = _burst_engine(cfg, params, 64, None)
    m_chunk, ttft_chunk = _burst_engine(cfg, params, 64, 16)
    # the first short's token no longer waits out the whole 64-token prefill
    assert min(ttft_chunk) < min(ttft_whole)
    # the per-step prefill work is bounded by the token budget; unchunked it
    # scales with the longest prompt (64-token prompt + a 6-token rider)
    assert max(m_chunk.prefill_tokens_trace) <= 16
    assert max(m_whole.prefill_tokens_trace) >= 64
    m_chunk2, _ = _burst_engine(cfg, params, 32, 16)
    assert max(m_chunk2.prefill_tokens_trace) <= 16   # invariant in long_len


def test_ttft_under_burst_improves_at_paper_scale():
    """Simulator, paper regime (34B on A100: a 6k-token prefill is ~0.7 s vs
    a ~45 ms decode step): chunking un-sticks the short prompts queued behind
    the head-of-line prefill — TTFT p50 AND p99 improve by multiples."""
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import Request, ServingSimulator
    cfg34 = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg34)
    wb = cfg34.param_count() * 2

    def run(step_tokens):
        sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                               kv_capacity_bytes=80e9 - wb - 2e9,
                               scheduler="cfs", offload_tier="fabric",
                               max_running=8, step_tokens=step_tokens)
        reqs = [Request(0, 0.0, 6000, 30)]
        reqs += [Request(i, 0.001 * i, 120, 30) for i in range(1, 13)]
        res = sim.run(reqs)
        ttfts = sorted(r.ttft - r.arrival for r in res.requests
                       if r.prompt_len == 120)
        ts = [e["t"] for e in res.timeline]
        steps = np.diff([0.0] + ts)
        return ttfts, float(max(steps))

    (whole, ms_whole), (chunked, ms_chunk) = run(None), run(256)
    assert chunked[len(chunked) // 2] < whole[len(whole) // 2] / 3.0   # p50
    assert chunked[-1] < whole[-1] / 2.0                               # p99
    # and the max scheduler-round time no longer carries the whole prefill
    assert ms_chunk < ms_whole / 2.0


# ---------------------------------------------------------------------------
# scheduling invariant: never silently skip placement
# ---------------------------------------------------------------------------
def test_place_raises_loudly_when_slots_exhausted():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_running=1, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST)
    r = eng.submit([1, 2, 3, 4], 2)
    eng._free_slots = []                              # simulate a plan bug
    with pytest.raises(SchedulingInvariantError, match="slot"):
        eng._place(Decision([r], [r], []))


# ---------------------------------------------------------------------------
# restore prefetch: transfers overlap compute
# ---------------------------------------------------------------------------
def test_prefetch_overlaps_restore_with_compute():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(4)]
    truth = [_greedy(cfg, params, p, 6) for p in prompts]

    def serve(prefetch):
        eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=REMOTE, step_tokens=16,
                            prefetch=prefetch)
        eng.pager.add_remote_lease("donor0", 2 ** 24)
        for p in prompts:
            eng.submit(p, 6)
        m = eng.run(400)
        got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
        assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
        return m

    m_sync = serve(False)
    m_pf = serve(True)
    assert m_sync.prefetched_restores == 0
    assert m_pf.prefetched_restores > 0
    assert m_pf.overlap_hidden_s > 0.0
    # prefetching hides transfer time behind compute: the clock only improves
    assert m_pf.sim_time <= m_sync.sim_time
    assert m_pf.sim_time < m_sync.sim_time - 0.5 * m_pf.overlap_hidden_s


def test_prefetch_misprediction_parks_back_on_new_arrival():
    """A submit() between steps can invalidate the peeked plan; the engine
    must re-park mispredicted prefetches so LOCAL only ever holds the
    planned run set (otherwise ensure_capacity can die mid-step later)."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(3)]
    truth = [_greedy(cfg, params, p, n)
             for p, n in zip(prompts, (8, 8, 4))]
    # prefix_cache=False: the final assert counts exact LOCAL pages after
    # drain, and the global prefix cache would (correctly) retain the
    # prompts' refcount-0 pages (cache residency is covered by
    # tests/test_prefix_cache.py)
    eng = ServingEngine(cfg, params, max_running=1, max_seq=64,
                        scheduler="cfs", slice_tokens=2, offload_tier=HOST,
                        step_tokens=16, prefetch=True, prefix_cache=False)
    eng.submit(prompts[0], 8)
    eng.submit(prompts[1], 8)
    for _ in range(100):
        eng.step()
        if eng.metrics.prefetched_restores:
            break
    assert eng.metrics.prefetched_restores > 0
    # the new arrival (vruntime 0) jumps the queue at the next boundary,
    # dropping the freshly-prefetched request from the planned run set
    eng.submit(prompts[2], 4)
    m = eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert eng.kv.aqua.tier_counts()["local"] == 1    # scratch page only


# ---------------------------------------------------------------------------
# jit-retrace guard (run explicitly by the tier-1 CI workflow)
# ---------------------------------------------------------------------------
def test_retrace_guard_trace_count_flat_across_prompt_lengths():
    """Shape buckets make the jit cache size independent of the prompt-length
    mix: a second wave of NEW distinct lengths must add zero traces."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)

    def serve(lengths):
        eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=HOST, step_tokens=16)
        for n in lengths:
            eng.submit(list(map(int, rng.integers(0, cfg.vocab_size, n))), 3)
        eng.run(400)

    lm.reset_trace_counts()
    serve([5, 9, 18, 27])
    c1 = lm.trace_counts()
    serve([6, 11, 22, 31])                            # all-new lengths
    c2 = lm.trace_counts()
    # the engine's sole entry point is the fused step: its trace count must
    # stay flat across a second wave of all-new distinct prompt lengths
    assert c2.get("serve_step", 0) == c1.get("serve_step", 0)
    # packed shapes live on the (chunk-bucket x row-bucket) ladder: with a
    # 16-token budget, Tc in {1, 8, 16}, chunk rows in {1, 2}, decode region
    # present or absent — a handful of traces, independent of prompt lengths
    assert c2.get("serve_step", 0) <= 8
