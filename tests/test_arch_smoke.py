"""Per-architecture smoke tests (reduced same-family configs, CPU).

For every assigned arch:
  * one train step: finite loss, correct logits shape
  * prefill + decode agree with the full forward pass (exact causality),
    using dropless MoE capacity at smoke scale (see moe_apply docstring).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ALL_SHAPES, TRAIN_4K, get_config, list_archs,
                           smoke_config)
from repro.models import api, lm

ASSIGNED = [
    "internvl2-1b", "rwkv6-3b", "gemma-7b", "qwen1.5-0.5b", "minicpm-2b",
    "gemma3-12b", "deepseek-v2-lite-16b", "dbrx-132b", "whisper-tiny",
    "jamba-v0.1-52b",
]


def _extras(cfg, B, key):
    ex = {}
    if cfg.family == "encdec":
        ex["enc_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.encdec.encoder_seq_len, cfg.d_model), jnp.float32)
    elif cfg.n_prefix_embeds:
        ex["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    return ex


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_smoke(name):
    cfg = smoke_config(get_config(name))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    B, T = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks, **_extras(cfg, B, jax.random.PRNGKey(2))}
    loss = api.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: api.loss_fn(p, cfg, batch))(params)
    gn = jax.tree.reduce(lambda a, b: a + b,
                         jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode_matches_forward(name):
    cfg = smoke_config(get_config(name))
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    mod = api.model_module(cfg)
    B, T, S = 2, 24, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab_size)
    ex = _extras(cfg, B, jax.random.PRNGKey(3))
    if cfg.family == "encdec":
        full, _ = mod.forward(params, cfg, toks, ex["enc_embeds"])
    elif cfg.n_prefix_embeds:
        full, _ = mod.forward(params, cfg, toks, prefix_embeds=ex["prefix_embeds"])
    else:
        full, _ = mod.forward(params, cfg, toks)
    assert not bool(jnp.isnan(full).any())

    P = cfg.n_prefix_embeds
    cache = api.init_decode_state(cfg, B, S, jnp.float32)
    tp = T - 4
    lg, cache = mod.prefill(params, cfg, toks[:, :tp], cache, **ex)
    errs = [float(jnp.abs(lg - full[:, P + tp - 1]).max())]
    for i in range(tp, T):
        pos = jnp.full((B,), P + i, jnp.int32)
        lg, cache = mod.decode_step(params, cfg, cache, toks[:, i], pos)
        errs.append(float(jnp.abs(lg - full[:, P + i]).max()))
    assert max(errs) < 2e-4, (name, errs)


@pytest.mark.parametrize("name", ASSIGNED)
def test_full_configs_registered(name):
    cfg = get_config(name)
    assert cfg.param_count() > 0
    assert cfg.n_layers % lm.group_size(cfg) == 0 or cfg.family == "encdec"


def test_sliding_window_ring_cache_smaller():
    cfg = smoke_config(get_config("gemma3-12b"))
    st = api.init_decode_state(cfg, 2, 1024, jnp.float32)
    # local layers hold only `window` slots; the global layer holds 1024
    slot_sizes = {k: v.k.shape[2] for k, v in st.items()}
    assert slot_sizes["sub5"] == 1024            # global
    assert all(v == cfg.sliding_window for k, v in slot_sizes.items() if k != "sub5")
