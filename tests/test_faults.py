"""Fault-tolerant tier domain: transfer-leg fault injection with bounded
retry, dynamic lease shrinkage with live page migration, permanent donor
loss with degrade-to-host recompute recovery, allocation rollback, the
typed error hierarchy, and the full-state invariant auditor — deterministic
scenarios, a seeded chaos loop, and a hypothesis property test (skipped
when hypothesis is not installed).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import errors as errs
from repro.core.aqua_tensor import (HOST, LOCAL, LOST, REMOTE, AquaTensor,
                                    TransferMeter)
from repro.core.faults import FaultEvent, FaultInjector, InvariantAuditor
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedStateRuntime

from _hypothesis_compat import given, settings, st

ARCH = "qwen1.5-0.5b"


def _tensor(**kw):
    args = dict(n_logical=64, page_shape=(4,), local_slots=8, host_slots=8,
                dtype=jnp.float32, meter=TransferMeter())
    args.update(kw)
    return AquaTensor(**args)


# ---------------------------------------------------------------------------
# typed error hierarchy
# ---------------------------------------------------------------------------
def test_error_hierarchy():
    for sub in (errs.PageLossError, errs.LeaseRevokedError,
                errs.TransferFaultError, errs.SchedulingInvariantError,
                errs.InvariantViolation, errs.CapacityError,
                errs.CancelledError, errs.EngineCrashError):
        assert issubclass(sub, errs.AquaError)
        assert issubclass(sub, RuntimeError)
    # the engine re-exports SchedulingInvariantError (it moved to errors.py)
    from repro.serving.engine import SchedulingInvariantError
    assert SchedulingInvariantError is errs.SchedulingInvariantError
    e = errs.PageLossError("gone", plane="kv", pages=[3, 4])
    assert e.plane == "kv" and e.pages == (3, 4)
    v = errs.InvariantViolation(["a", "b"])
    assert v.violations == ("a", "b") and "a" in str(v)
    c = errs.CancelledError("gone", rid=7, reason="deadline")
    assert c.rid == 7 and c.reason == "deadline"


# ---------------------------------------------------------------------------
# allocation rollback (all-or-nothing across a failing multi-page alloc)
# ---------------------------------------------------------------------------
def test_allocate_rollback_when_tiers_exhaust_midway():
    t = _tensor(local_slots=3, host_slots=2)     # 5 physical slots total
    before_local = len(t._free_local)
    before_host = len(t._free_host)
    with pytest.raises(MemoryError, match="all tiers full"):
        t.allocate(6)                            # fails on the 6th slot
    # every slot the failing call took is back on its free list
    assert len(t._free_local) == before_local
    assert len(t._free_host) == before_host
    assert (t.page_table[:, 0] == -1).all()
    assert (t.page_refs == 0).all()
    # the pool still works after the rollback
    lps = t.allocate(5)
    assert len(lps) == 5


@pytest.mark.parametrize("plane_idx", [0, 1, 2])
def test_ensure_capacity_rollback_at_each_plane_boundary(plane_idx):
    """Multi-plane ensure_capacity is all-or-nothing: exhaust the pool of
    plane ``plane_idx`` (kv + the two mamba state planes of a hybrid) so
    the per-step allocation fails there, and assert every page an EARLIER
    plane already took was handed back — no leak, no partial rows."""
    cfg = smoke_config(get_config("jamba-v0.1-52b"))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                           host_pages=0)
    planes = list(kv.planes.values())
    if plane_idx >= len(planes):
        pytest.skip(f"family has {len(planes)} planes")
    victim = planes[plane_idx]
    # drain the victim plane's LOCAL pool (its only tier: host_pages=0,
    # no lease), keeping one page so a 1-page request part-fits
    drained = victim.aqua.allocate(victim.aqua.local_free)
    auditor = InvariantAuditor()
    snap = {p.name: p.aqua.tier_counts() for p in planes}
    with pytest.raises(MemoryError):
        kv.ensure_capacity(7, 40)
    assert all(7 not in p.pages for p in planes), "partial rows leaked"
    assert {p.name: p.aqua.tier_counts() for p in planes} == snap
    victim.aqua.free(drained)
    # and the runtime still serves: the same request fits after the drain
    kv.ensure_capacity(7, 40)
    assert not auditor.check(kv)
    kv.release(7)


def test_make_writable_clone_rollback_frees_the_clone():
    """A CoW clone that spills off LOCAL (pool full) must be handed back
    instead of leaking on the spill tier — the block table keeps pointing
    at the shared original."""
    cfg = smoke_config(get_config(ARCH))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                           host_pages=64)
    toks = list(range(100, 109))                 # 9 tokens: one full page
    kv.adopt_prefix(1, toks)
    kv.ensure_capacity(1, 9)
    kv.register_prefix(1, 9)
    assert kv.adopt_prefix(2, toks) == 8         # page 0 now shared
    kv.ensure_capacity(2, 9)
    plane = kv.planes["kv"]
    filler = plane.aqua.allocate(plane.aqua.local_free)  # LOCAL now full
    before = plane.aqua.tier_counts()
    with pytest.raises(MemoryError):
        kv.make_writable(2, 0, 9)                # clone would spill to HOST
    assert plane.aqua.tier_counts() == before, "spilled clone leaked"
    plane.aqua.free(filler)
    kv.make_writable(2, 0, 9)                    # with room it clones fine
    assert kv.cow_copies > 0


# ---------------------------------------------------------------------------
# transient transfer-leg faults: bounded retry, backoff pricing
# ---------------------------------------------------------------------------
def test_leg_retry_converges_and_prices_backoff():
    faults = FaultInjector(seed=11, leg_fault_rate=0.8, max_consecutive=2)
    t = _tensor(faults=faults)
    lps = t.allocate(6)
    payload = jnp.arange(6 * 4, dtype=jnp.float32).reshape(6, 4)
    t.write_local(lps, payload)
    clean = _tensor()
    c = clean.allocate(6)
    clean.write_local(c, payload)
    for tensor, pages in ((t, lps), (clean, c)):
        tensor.offload(pages, prefer=HOST)
        tensor.ensure_local(pages)
    # faulted run: same data back, retries counted and priced
    np.testing.assert_array_equal(np.asarray(t.read(lps)),
                                  np.asarray(clean.read(c)))
    assert t.meter.retries_host > 0
    assert faults.leg_faults_injected == t.meter.retries_host
    assert t.meter.sim_time > clean.meter.sim_time
    # retries are priced but never counted as messages
    assert t.meter.messages_host == clean.meter.messages_host


def test_leg_guard_raises_past_retry_budget():
    # a leg that fails 10x consecutively exceeds the 2-retry budget before
    # the injector's forced success can kick in
    faults = FaultInjector(seed=0, leg_fault_rate=1.0, max_consecutive=10,
                           max_leg_retries=2)
    t = _tensor(faults=faults)
    lps = t.allocate(2)
    t.write_local(lps, jnp.zeros((2, 4), jnp.float32))
    with pytest.raises(errs.TransferFaultError) as ei:
        t.offload(lps, prefer=HOST)
    assert ei.value.attempts == 2 and ei.value.tier == HOST


def test_fault_injection_is_seed_deterministic():
    def draws(seed):
        f = FaultInjector(seed=seed, leg_fault_rate=0.5)
        return [f.leg_fails(REMOTE, "d0") for _ in range(32)]

    assert draws(7) == draws(7)
    assert draws(7) != draws(8)
    # the consecutive-failure cap guarantees convergence for ANY seed
    f = FaultInjector(seed=3, leg_fault_rate=1.0, max_consecutive=3)
    run = [f.leg_fails(HOST, None) for _ in range(20)]
    assert max(len(s) for s in
               "".join("T" if x else "F" for x in run).split("F")) <= 3


# ---------------------------------------------------------------------------
# dynamic lease shrinkage: live migration off the shrinking donor
# ---------------------------------------------------------------------------
def test_shrink_lease_migrates_excluding_the_shrinking_donor():
    t = _tensor(local_slots=4, host_slots=16)
    t.add_remote_lease("d0", 8)
    t.add_remote_lease("d1", 8)
    lps = t.allocate(8, prefer=REMOTE)           # fills d0 entirely
    assert (t.page_table[lps, 0] == REMOTE).all()
    assert (t.page_table[lps, 2] == 0).all()
    moved = t.shrink_lease("d0", 4)              # reclaim the TOP 4 slots
    assert moved == 4
    assert t.remote_capacity["d0"] == 4
    # migrated pages went to d1 (or host), never back onto d0's low slots
    relocated = lps[np.asarray(t.page_table[lps, 2] != 0)
                    | np.asarray(t.page_table[lps, 0] != REMOTE)]
    assert len(relocated) == 4
    on_d0 = [lp for lp in lps
             if t.page_table[lp, 0] == REMOTE and t.page_table[lp, 2] == 0]
    assert all(t.page_table[lp, 1] < 4 for lp in on_d0)
    # shrink to zero drops the lease entirely
    t.shrink_lease("d0", 4)
    assert "d0" not in t.remote_pools and "d0" not in t.remote_capacity
    with pytest.raises(errs.LeaseRevokedError):
        t.shrink_lease("d0", 1)


def test_shrink_preserves_payload_bits():
    t = _tensor(local_slots=8, host_slots=16)
    t.add_remote_lease("d0", 8)
    t.add_remote_lease("d1", 8)
    rng = np.random.default_rng(5)
    payload = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    lps = t.allocate(8)
    t.write_local(lps, payload)
    t.offload(lps, prefer=REMOTE)
    t.shrink_lease("d0", 8)
    t.ensure_local(lps)
    np.testing.assert_array_equal(np.asarray(t.read(lps)),
                                  np.asarray(payload))


# ---------------------------------------------------------------------------
# permanent donor loss: LOST tier, PageLossError surfaces
# ---------------------------------------------------------------------------
def test_fail_donor_marks_lost_and_every_touch_raises():
    faults = FaultInjector(seed=0)
    t = _tensor(faults=faults)
    t.add_remote_lease("d0", 8)
    lps = t.allocate(4)
    t.write_local(lps, jnp.ones((4, 4), jnp.float32))
    t.offload(lps, prefer=REMOTE)
    lost = t.fail_donor("d0")
    assert sorted(int(x) for x in lost) == sorted(int(x) for x in lps)
    assert (t.page_table[lps, 0] == LOST).all()
    assert t.tier_counts()["lost"] == 4
    assert faults.donor_lost("d0")
    for op in (lambda: t.read(lps), lambda: t.ensure_local(lps),
               lambda: t.block_tables([list(lps)], pad_to=8),
               lambda: t.offload(lps, prefer=HOST)):
        with pytest.raises(errs.PageLossError):
            op()
    # a lost donor can never lease again
    with pytest.raises(errs.LeaseRevokedError):
        t.add_remote_lease("d0", 8)
    # recovery path: freeing the lost pages clears them for reuse
    t.free(lps)
    assert (t.page_table[lps, 0] == -1).all()
    assert "lost" not in t.tier_counts()


# ---------------------------------------------------------------------------
# invariant auditor: green on healthy state, loud on seeded corruption
# ---------------------------------------------------------------------------
def test_auditor_green_then_detects_seeded_corruption():
    cfg = smoke_config(get_config(ARCH))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    kv.ensure_capacity(1, 20)
    kv.ensure_capacity(2, 12)
    auditor = InvariantAuditor()
    assert auditor.check(kv) == []
    auditor.audit(kv)                            # green: must not raise
    plane = kv.planes["kv"]
    lp = int(plane.pages[1][0][0])
    plane.aqua.page_refs[lp] += 1                # phantom reference
    assert auditor.check(kv)
    with pytest.raises(errs.InvariantViolation):
        auditor.audit(kv)
    plane.aqua.page_refs[lp] -= 1
    assert auditor.check(kv) == []
    # corrupt the free list: a slot both free and occupied
    plane.aqua._free_local.append(int(plane.aqua.page_table[lp, 1]))
    assert any("free" in v or "occupancy" in v for v in auditor.check(kv))


# ---------------------------------------------------------------------------
# engine end-to-end: donor loss -> recompute, shrink -> migration,
# bit-identical outputs either way, auditor green after every step
# ---------------------------------------------------------------------------
def _engine_prompts(cfg, n=3, length=8, seed=0):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(0, cfg.vocab_size, length)))
            for _ in range(n)]


def _build_engine(cfg, params, prompts, faults=None, audit=False):
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=1,
                           prefix_sharing=False)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=REMOTE,
                        kv=kv, faults=faults, audit=audit, prefetch=False)
    eng.pager.add_remote_lease("d0", 2 ** 24)
    for p in prompts:
        eng.submit(p, 6)
    return eng


def test_engine_recovers_from_donor_loss_and_lease_shrink_bit_identical():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _engine_prompts(cfg)

    eng0 = _build_engine(cfg, params, prompts)
    eng0.run(500)
    base = {tuple(r.prompt_tokens): r.generated for r in eng0.finished}
    assert len(base) == len(prompts)

    # probe: find the first step after which pages sit on the donor
    probe = _build_engine(cfg, params, prompts)
    hit = None
    for _ in range(200):
        if not (probe.waiting or probe.running):
            break
        probe.step()
        if probe.kv.stats()["tiers"].get("remote", 0) > 0:
            hit = probe.metrics.steps
            break
    assert hit is not None, "CFS under page pressure must park remotely"

    # donor loss at that step: victims recompute from the prompt
    fi = FaultInjector(seed=3, events=[
        FaultEvent(kind="donor_loss", donor="d0", at_step=hit)])
    eng = _build_engine(cfg, params, prompts, faults=fi, audit=True)
    m = eng.run(500)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert m.donor_losses == 1 and m.recomputes > 0 and m.recovered_rids
    assert got == base, "recomputed requests must regenerate bit-identically"
    assert eng.auditor.audits == m.steps
    # capacity re-planned: the budget contracted to the surviving tiers
    assert (np.asarray(eng.sched.page_budget)
            <= np.asarray(eng.kv.page_budget)).all()

    # lease shrink at the same step: pages live-migrate, nothing recomputes
    fi2 = FaultInjector(seed=5, events=[
        FaultEvent(kind="lease_shrink", donor="d0", frac=1.0, at_step=hit)])
    eng2 = _build_engine(cfg, params, prompts, faults=fi2, audit=True)
    m2 = eng2.run(500)
    got2 = {tuple(r.prompt_tokens): r.generated for r in eng2.finished}
    assert m2.lease_shrinks == 1 and m2.migrated_pages > 0
    assert m2.recomputes == 0
    assert got2 == base, "migrated requests must keep their exact KV"


def test_engine_transient_leg_faults_priced_not_fatal():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _engine_prompts(cfg, seed=1)
    eng0 = _build_engine(cfg, params, prompts)
    m0 = eng0.run(500)
    base = {tuple(r.prompt_tokens): r.generated for r in eng0.finished}
    fi = FaultInjector(seed=9, leg_fault_rate=0.3)
    eng = _build_engine(cfg, params, prompts, faults=fi, audit=True)
    m = eng.run(500)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert got == base
    assert m.leg_retries > 0
    assert m.sim_time > m0.sim_time              # the retries cost time


# ---------------------------------------------------------------------------
# simulator: fault schedules on the analytic clock
# ---------------------------------------------------------------------------
def _sim34(faults=None, **kw):
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import ServingSimulator
    cfg = get_config("aqua-codellama-34b")
    wb = cfg.param_count() * 2
    args = dict(weight_bytes=wb, kv_capacity_bytes=80e9 - wb - 2e9,
                scheduler="cfs", offload_tier="fabric", max_running=4,
                step_tokens=256, faults=faults)
    args.update(kw)
    return ServingSimulator(A100_NVLINK, ModelCost.from_config(cfg), **args)


def _sim_requests(n=16, seed=2):
    from repro.core.simulator import Request
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / 80.0, n))
    return [Request(i, float(arr[i]), int(rng.integers(300, 800)),
                    int(rng.integers(40, 120))) for i in range(n)]


def test_simulator_capacity_error_is_typed():
    with pytest.raises(errs.CapacityError):
        _sim34(kv_capacity_bytes=0.0).run(_sim_requests(1))


def test_simulator_fault_events_and_retry_pricing():
    def run(faults):
        sim = _sim34(faults=faults)
        res = sim.run(_sim_requests())
        assert all(r.finish is not None for r in res.requests)
        return sim, res

    sim0, res0 = run(None)
    t0 = max(r.finish for r in res0.requests)

    fi = FaultInjector(seed=4, leg_fault_rate=0.2, events=[
        FaultEvent(kind="donor_loss", donor="d0", frac=1.0,
                   at_time=t0 * 0.3),
        FaultEvent(kind="lease_shrink", donor="d1", frac=0.5,
                   at_time=t0 * 0.5)])
    sim1, res1 = run(fi)
    assert sim1.leg_retries > 0
    assert sim1.donor_losses == 1 and sim1.lease_shrinks == 1
    assert len(fi.events_fired) == 2
    # every request still completes, later than the fault-free run
    t1 = max(r.finish for r in res1.requests)
    assert t1 > t0
    # at least one parked context was reset and recomputed
    assert any(r.recovered for r in res1.requests)


# ---------------------------------------------------------------------------
# chaos: random op interleavings against the auditor
# ---------------------------------------------------------------------------
def _chaos_round(seed: int, n_ops: int = 80):
    rng = np.random.default_rng(seed)
    cfg = smoke_config(get_config(ARCH))
    faults = FaultInjector(seed=seed, leg_fault_rate=0.05)
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    kv.attach_faults(faults)
    page_bytes = kv.planes["kv"].aqua.page_bytes
    kv.add_remote_lease("d0", 64 * page_bytes)
    kv.add_remote_lease("d1", 64 * page_bytes)
    auditor = InvariantAuditor()
    # three prompt families drive the radix cache: new requests adopt a
    # family prefix (sometimes with a diverged tail) and register their
    # growth, so releases leave CACHED pages behind and later growth
    # triggers revival, eviction and cold-first demotion mid-chaos
    fam = [list(map(int, rng.integers(0, 50, 60))) for _ in range(3)]
    live: dict = {}                              # rid -> resident tokens
    prompts: dict = {}                           # rid -> token identity
    parked: set = set()
    next_rid = 0
    for _ in range(n_ops):
        op = rng.choice(["grow", "park", "restore", "release",
                         "shrink", "fail"],
                        p=[0.35, 0.2, 0.2, 0.15, 0.07, 0.03])
        try:
            if op == "grow":
                rid = (int(rng.choice(list(live))) if live and rng.random() < 0.5
                       else next_rid)
                if rid == next_rid:
                    next_rid += 1
                    live[rid] = 0
                    base = fam[int(rng.integers(len(fam)))]
                    if rng.random() < 0.4:       # mid-prompt divergence
                        cut = int(rng.integers(8, 60))
                        prompts[rid] = base[:cut] + [t + 1 for t in base[cut:]]
                    else:
                        prompts[rid] = list(base)
                    live[rid] = kv.adopt_prefix(rid, prompts[rid])
                if rid in parked:
                    kv.restore(rid)
                    parked.discard(rid)
                tok = min(live[rid] + int(rng.integers(1, 12)), 60)
                kv.ensure_capacity(rid, tok)
                live[rid] = tok
                kv.register_prefix(rid, tok)
            elif op == "park" and live:
                rid = int(rng.choice([r for r in live if r not in parked]
                                     or list(live)))
                if rid not in parked and live[rid] > 0:
                    kv.park(rid, live[rid],
                            prefer=REMOTE if rng.random() < 0.7 else HOST)
                    parked.add(rid)
            elif op == "restore" and parked:
                rid = int(rng.choice(sorted(parked)))
                if kv.can_restore(rid):
                    kv.restore(rid)
                    parked.discard(rid)
            elif op == "release" and live:
                rid = int(rng.choice(sorted(live)))
                kv.release(rid)
                live.pop(rid)
                prompts.pop(rid, None)
                parked.discard(rid)
            elif op == "shrink":
                donor = str(rng.choice(["d0", "d1"]))
                if any(donor in p.aqua.remote_pools
                       for p in kv.planes.values()):
                    kv.shrink_lease(donor, float(rng.uniform(0.2, 0.8)))
            elif op == "fail":
                donor = str(rng.choice(["d0", "d1"]))
                victims = kv.fail_donor(donor)
                for rid in victims:              # recovery: drop the victims
                    kv.release(rid)
                    live.pop(rid, None)
                    prompts.pop(rid, None)
                    parked.discard(rid)
        except (MemoryError, errs.LeaseRevokedError, errs.PageLossError):
            pass                                 # legal under chaos
        violations = auditor.check(kv)
        assert not violations, (seed, op, violations)
    for rid in list(live):
        kv.release(rid)
    assert auditor.check(kv) == []


def test_chaos_interleavings_keep_every_invariant():
    for seed in (0, 1, 2):
        _chaos_round(seed)


@given(seed=st.integers(min_value=0, max_value=2 ** 16))
@settings(max_examples=15, deadline=None)
def test_chaos_property_random_seeds(seed):
    _chaos_round(seed, n_ops=30)


# ---------------------------------------------------------------------------
# engine-level chaos: random lifecycle-op interleavings (step / submit /
# cancel-at-any-state / drain+resume / snapshot-restore-swap) against the
# full-state auditor after EVERY op
# ---------------------------------------------------------------------------
def _engine_chaos_round(seed: int, cfg, params, n_ops: int = 30):
    rng = np.random.default_rng(seed)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=4, offload_tier=HOST,
                        step_tokens=8, prefetch=False)
    auditor = InvariantAuditor()
    for i in range(n_ops):
        op = rng.choice(["step", "submit", "cancel", "drain", "snapshot"],
                        p=[0.45, 0.25, 0.15, 0.05, 0.10])
        if op == "submit":
            n = int(rng.integers(4, 16))
            toks = list(map(int, 1 + rng.integers(0, cfg.vocab_size - 1, n)))
            eng.submit(toks, int(rng.integers(1, 6)))
        elif op == "cancel":
            live = [r.rid for r in eng.waiting + eng.running]
            if live:
                eng.cancel(int(rng.choice(live)))
        elif op == "drain":
            eng.drain()
            eng.resume()
        elif op == "snapshot":
            eng = ServingEngine.restore(cfg, params, eng.snapshot())
            auditor = InvariantAuditor()     # the mesh check is per-engine
        else:
            eng.step()
        violations = auditor.check(eng.kv, engine=eng)
        assert not violations, (seed, i, op, violations)
    eng.run(500)
    assert not (eng.waiting or eng.running)
    assert auditor.check(eng.kv, engine=eng) == []


def test_engine_chaos_lifecycle_ops_keep_every_invariant():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    for seed in (0, 1, 2):
        _engine_chaos_round(seed, cfg, params)


# ---------------------------------------------------------------------------
# mesh: requests surviving donor loss via migration stay bit-identical
# across the real-collective and single-device backends (slow tier)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_mesh_shrink_migration_bit_identical_vs_single_device():
    from repro.distributed.mesh_tiers import MeshTierDomain
    if not MeshTierDomain.available():
        pytest.skip("mesh tiers need a single-process mesh with >= 2 devices")
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _engine_prompts(cfg, seed=2)

    def serve(mesh, faults=None):
        kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=1,
                               prefix_sharing=False, mesh=mesh)
        eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=REMOTE, kv=kv, faults=faults,
                            audit=True, prefetch=False)
        eng.pager.add_remote_lease("d0", 2 ** 24)
        eng.pager.add_remote_lease("d1", 2 ** 24)
        for p in prompts:
            eng.submit(p, 6)
        m = eng.run(500)
        return {tuple(r.prompt_tokens): r.generated
                for r in eng.finished}, m

    base, _ = serve(None)
    fi = FaultInjector(seed=1, events=[
        FaultEvent(kind="lease_shrink", donor="d0", frac=1.0, at_step=4)])
    mesh_got, m = serve(MeshTierDomain(), faults=fi)
    assert mesh_got == base
    assert m.lease_shrinks == 1
