"""Training substrate tests: optimizer correctness, schedules, checkpoint
atomicity + restart determinism, microbatch-accumulation equivalence,
gradient-compression error feedback, straggler/rebalance policies.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.models import api
from repro.training import checkpoint as ckpt
from repro.training.compression import (compress, compressed_psum, decompress,
                                        init_error_buffers)
from repro.training.data import DataConfig, make_batch
from repro.training.elastic import RebalancePolicy
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      cosine_schedule, global_norm, wsd_schedule)
from repro.training.train_loop import TrainConfig, make_train_step, train


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    st_ = adamw_init(params, ocfg)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, st_, _ = adamw_update(g, st_, params, ocfg)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    ocfg = AdamWConfig(lr=1.0, weight_decay=0.0, grad_clip=1.0)
    st_ = adamw_init(params, ocfg)
    g = {"w": jnp.full((4,), 1e6)}
    p2, _, stats = adamw_update(g, st_, params, ocfg)
    assert float(stats["grad_norm"]) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 1.5      # clipped step ~ lr


def test_schedules_shapes():
    cos = cosine_schedule(1e-3, warmup=10, total=100)
    wsd = wsd_schedule(1e-3, warmup=10, total=100, decay_frac=0.2)
    assert float(cos(jnp.asarray(0))) == 0.0
    assert abs(float(cos(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(cos(jnp.asarray(100))) < 2e-4
    assert abs(float(wsd(jnp.asarray(50))) - 1e-3) < 1e-9   # stable plateau
    assert float(wsd(jnp.asarray(100))) < 2e-5              # sharp decay


def test_microbatch_accumulation_matches_full_batch():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    dcfg = DataConfig(seed=0, batch=4, seq_len=32)
    batch = make_batch(dcfg, cfg, 0)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=1e-3)
    s0 = adamw_init(params, ocfg)
    p1, _, st1 = make_train_step(cfg, ocfg, TrainConfig(micro_batches=1))(params, s0, batch)
    p4, _, st4 = make_train_step(cfg, ocfg, TrainConfig(micro_batches=4))(params, s0, batch)
    assert abs(float(st1["loss"]) - float(st4["loss"])) < 1e-5
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-5          # f32 accumulation-order noise only


def test_remat_matches_no_remat():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    dcfg = DataConfig(seed=0, batch=2, seq_len=32)
    batch = make_batch(dcfg, cfg, 0)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    g1 = jax.grad(lambda p: api.loss_fn(p, cfg, batch, remat=False))(params)
    g2 = jax.grad(lambda p: api.loss_fn(p, cfg, batch, remat=True))(params)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert d < 1e-5


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ckpt.save({"params": params}, str(tmp_path), 7)
    assert ckpt.latest_step(str(tmp_path)) == 7
    back = ckpt.restore({"params": params}, str(tmp_path), 7)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a torn checkpoint (no COMMITTED marker) is invisible to discovery
    os.makedirs(tmp_path / "step_9")
    (tmp_path / "step_9" / "manifest.json").write_text("{}")
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_failure_injection_and_restart_resumes_exactly(tmp_path):
    """Train 12 steps with a crash at 8; restart resumes from the step-6
    checkpoint and converges to the same trajectory as an uninterrupted run
    (deterministic data + checkpointed optimizer state)."""
    cfg = smoke_config(get_config("qwen1.5-0.5b")).replace(n_layers=2)
    dcfg = DataConfig(seed=1, batch=2, seq_len=16)
    ocfg = AdamWConfig(lr=1e-3)

    ref = train(cfg, dcfg, ocfg, TrainConfig(steps=12), seed=0)

    tc = TrainConfig(steps=12, ckpt_dir=str(tmp_path / "ck"), ckpt_every=3)
    with pytest.raises(RuntimeError, match="injected node failure"):
        train(cfg, dcfg, ocfg, tc, seed=0, fail_at=8)
    resumed = train(cfg, dcfg, ocfg, tc, seed=0)    # restart: resumes at ckpt
    np.testing.assert_allclose(ref["losses"][-3:], resumed["losses"][-3:],
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000))
def test_compression_error_feedback_bounded(seed):
    """EF property: accumulated quantization error stays O(scale), and the
    running sum of decompressed grads tracks the true sum."""
    rng = np.random.default_rng(seed)
    g_true = jnp.asarray(rng.standard_normal(64), jnp.float32)
    err = jnp.zeros(64)
    acc_true = np.zeros(64)
    acc_q = np.zeros(64)
    for t in range(30):
        g = g_true * (0.9 ** t)
        c, err = compress(g, err)
        acc_true += np.asarray(g)
        acc_q += np.asarray(decompress(c))
    scale = float(jnp.max(jnp.abs(g_true))) / 127.0
    assert float(jnp.abs(err).max()) <= scale * 1.01
    np.testing.assert_allclose(acc_q, acc_true, atol=2 * scale)


def test_compressed_psum_matches_mean():
    import jax
    devs = jax.devices()
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(devs[:1]), ("dp",))
    g = jnp.asarray(np.random.default_rng(0).standard_normal((1, 32)), jnp.float32)
    err = jnp.zeros((1, 32))
    from repro.distributed.sharding import shard_map_compat
    f = shard_map_compat(lambda g, e: compressed_psum(g[0], e[0], "dp"),
                         mesh, (P("dp"), P("dp")), P(), check=False)
    out, _ = f(g, err)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g[0]),
                               atol=float(jnp.abs(g).max()) / 100)


def test_rebalance_policy_shrinks_slow_shard():
    pol = RebalancePolicy(n_shards=4)
    sizes = pol.bucket_sizes(64, [1.0, 1.0, 1.0, 3.0])   # shard 3 is a straggler
    assert sum(sizes) == 64
    assert sizes[3] < min(sizes[:3])


def test_wsd_schedule_assigned_to_minicpm():
    """The minicpm-2b config pairs with WSD per its assignment note."""
    cfg = get_config("minicpm-2b")
    assert cfg.name == "minicpm-2b"
    lr = wsd_schedule(1e-2, 10, 1000)
    vals = [float(lr(jnp.asarray(s))) for s in (5, 500, 999)]
    assert vals[0] < vals[1] and vals[2] < vals[1] / 10
