"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU), plus hypothesis property tests on the invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.kv_gather.kernel import gather_pages, scatter_pages
from repro.kernels.kv_gather.ref import gather_pages_ref, scatter_pages_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.rwkv6_wkv.kernel import wkv6
from repro.layers.rwkv6 import wkv6_ref


def _rand(rng, shape, dtype):
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 3e-5, jnp.bfloat16: 3e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,K,hd,causal,window", [
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 256, 256, 4, 4, 32, True, 64),      # sliding window
    (2, 64, 192, 6, 2, 64, True, 0),        # right-aligned chunk (Sq < Sk)
    (1, 128, 128, 2, 2, 128, False, 0),     # bidirectional
    (1, 64, 64, 8, 1, 256, True, 0),        # MQA, gemma head_dim
])
def test_flash_attention_sweep(B, Sq, Sk, H, K, hd, causal, window, dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (B, Sq, H, hd), dtype)
    k = _rand(rng, (B, Sk, K, hd), dtype)
    v = _rand(rng, (B, Sk, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=TOL[dtype])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128]), st.sampled_from([1, 2, 4]),
       st.sampled_from([32, 64]))
def test_flash_attention_property(B, S, G, hd):
    """Property: softmax rows are convex combinations -> output within V hull."""
    rng = np.random.default_rng(B * S + G)
    K = 2
    q = _rand(rng, (B, S, K * G, hd), jnp.float32)
    k = _rand(rng, (B, S, K, hd), jnp.float32)
    v = _rand(rng, (B, S, K, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    assert bool(jnp.isfinite(out).all())
    assert float(out.max()) <= float(v.max()) + 1e-4
    assert float(out.min()) >= float(v.min()) - 1e-4


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,hd,P,page,pps", [
    (2, 4, 2, 64, 16, 8, 4),
    (3, 6, 2, 32, 32, 16, 6),
    (1, 8, 8, 128, 8, 8, 8),                # MHA
    (4, 8, 1, 64, 64, 32, 4),               # MQA
])
def test_paged_attention_sweep(B, H, K, hd, P, page, pps, dtype):
    rng = np.random.default_rng(1)
    q = _rand(rng, (B, H, hd), dtype)
    kp = _rand(rng, (K, P, page, hd), dtype)
    vp = _rand(rng, (K, P, page, hd), dtype)
    bt = jnp.asarray(rng.integers(0, P, (B, pps)), jnp.int32)
    ln = jnp.asarray(rng.integers(1, pps * page + 1, (B,)), jnp.int32)
    out = paged_attention(q, kp, vp, bt, ln, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=TOL[dtype])


def test_paged_attention_matches_contiguous():
    """Paged result == contiguous attention when pages are laid out in order."""
    rng = np.random.default_rng(2)
    B, H, K, hd, page, pps = 2, 4, 2, 64, 8, 4
    S = page * pps
    kc = _rand(rng, (B, S, K, hd), jnp.float32)
    vc = _rand(rng, (B, S, K, hd), jnp.float32)
    q = _rand(rng, (B, 1, H, hd), jnp.float32)
    ref = flash_attention_ref(q, kc, vc, causal=True)[:, 0]
    # lay pages contiguously: page p of seq b -> pool id b*pps+p
    kp = kc.reshape(B, pps, page, K, hd).transpose(3, 0, 1, 2, 4).reshape(K, B * pps, page, hd)
    vp = vc.reshape(B, pps, page, K, hd).transpose(3, 0, 1, 2, 4).reshape(K, B * pps, page, hd)
    bt = jnp.asarray([[b * pps + p for p in range(pps)] for b in range(B)], jnp.int32)
    ln = jnp.full((B,), S, jnp.int32)
    out = paged_attention(q[:, 0], kp, vp, bt, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ---------------------------------------------------------------------------
# kv gather / scatter (AQUA coalescing)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("P,page,d,n", [(16, 8, 32, 5), (64, 16, 128, 64), (8, 4, 8, 1)])
def test_kv_gather_sweep(P, page, d, n, dtype):
    rng = np.random.default_rng(3)
    if dtype == jnp.int8:
        pool = jnp.asarray(rng.integers(-100, 100, (P, page, d)), dtype)
    else:
        pool = _rand(rng, (P, page, d), dtype)
    ids = jnp.asarray(rng.choice(P, n, replace=False), jnp.int32)
    g = gather_pages(pool, ids, interpret=True)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(gather_pages_ref(pool, ids)))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 32), st.integers(1, 16), st.data())
def test_gather_scatter_roundtrip(P, n, data):
    """Property: scatter(gather(pool, ids), ids) == pool (page permutation id)."""
    n = min(n, P)
    rng = np.random.default_rng(P * 31 + n)
    pool = jnp.asarray(rng.standard_normal((P, 8, 16)), jnp.float32)
    ids = jnp.asarray(rng.choice(P, n, replace=False), jnp.int32)
    staging = gather_pages(pool, ids, interpret=True)
    back = scatter_pages(pool, staging, ids, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(pool))
    # and scattering new data touches exactly the listed pages
    new = jnp.ones_like(staging) * 7.0
    out = scatter_pages(pool, new, ids, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[ids]), np.asarray(new))
    untouched = np.setdiff1d(np.arange(P), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(out[untouched]), np.asarray(pool[untouched]))


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,hd,wmax", [
    (2, 64, 3, 32, 0.1),
    (1, 128, 2, 64, 1.0),
    (2, 96, 4, 32, 5.0),                    # strong decay stress
])
def test_wkv6_sweep(B, T, H, hd, wmax, dtype):
    rng = np.random.default_rng(4)
    r = _rand(rng, (B, T, H, hd), dtype)
    k = _rand(rng, (B, T, H, hd), dtype)
    v = _rand(rng, (B, T, H, hd), dtype)
    w = -jnp.asarray(rng.uniform(1e-3, wmax, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, hd, hd)), jnp.float32) * 0.1
    y, sT = wkv6(r, k, v, w, u, s0, chunk=32, interpret=True)
    yr, sTr = wkv6_ref(r, k, v, w, u, s0)
    # with weak decay the state accumulates to |y| ~ 1e2: bf16 output rounding
    # is ~0.4% relative, so compare with rtol + atol
    tol = dict(rtol=1e-3, atol=5e-4) if dtype == jnp.float32 else dict(rtol=2e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sTr), **tol)


def test_wkv6_chunk_invariance():
    """Property: output independent of chunk size (exactness of chunking)."""
    rng = np.random.default_rng(5)
    B, T, H, hd = 1, 128, 2, 32
    r, k, v = (jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32) for _ in range(3))
    w = -jnp.asarray(rng.uniform(1e-3, 2.0, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    outs = [wkv6(r, k, v, w, u, s0, chunk=c, interpret=True)[0] for c in (16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=5e-4)
