"""Mesh-real memory tiers: donor leases as PEER-device slabs, one collective
per (tier, donor) leg, mesh-vs-single-device bit-exactness per family,
donor reclaim mid-flight, re-lease bookkeeping, and clock calibration.

The CI box forces a 4-way host-platform device mesh (conftest.py sets
``--xla_force_host_platform_device_count=4``), so every test here runs the
REAL collective path — ``shard_map`` + ``ppermute`` — just on host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import (HOST, LOCAL, REMOTE, AquaTensor,
                                    TransferMeter)
from repro.core.perfmodel import TPU_V5E, fit_link_model
from repro.distributed.mesh_tiers import MeshTierDomain
from repro.models import api, lm
from repro.serving.kv_cache import PagedStateRuntime
from repro.serving.scheduler import bucket_tokens

pytestmark = pytest.mark.skipif(
    not MeshTierDomain.available(),
    reason="mesh tiers need a single-process mesh with >= 2 devices")


def _tensor(dom, *, slots=8, page=(4, 6)):
    a = AquaTensor(n_logical=32, page_shape=page, local_slots=slots,
                   host_slots=slots, dtype=jnp.float32,
                   meter=TransferMeter(), mesh=dom)
    a.add_remote_lease("d0", slots)
    return a


# ---------------------------------------------------------------------------
# the lease is a real peer slab; transfers are bit-exact round trips
# ---------------------------------------------------------------------------
def test_donor_pool_resident_on_peer_device():
    dom = MeshTierDomain()
    a = _tensor(dom)
    pool = a.remote_pools["d0"]
    dst = dom.donor_device("d0")
    assert dst != 0                       # device 0 serves, never donates
    by_dev = {s.device: s.index for s in pool.addressable_shards}
    donor_dev = dom.devices[dst]
    assert donor_dev in by_dev            # the slab really lives on the peer
    assert by_dev[donor_dev][0] == slice(dst, dst + 1)


def test_offload_restore_round_trip_bit_exact():
    dom = MeshTierDomain()
    a = _tensor(dom)
    rng = np.random.default_rng(0)
    lps = a.allocate(5)
    payload = jnp.asarray(rng.standard_normal((5,) + a.page_shape), jnp.float32)
    a.write_local(lps, payload)

    a.offload(lps, prefer=REMOTE)
    assert (a.page_table[lps, 0] == REMOTE).all()
    np.testing.assert_array_equal(np.asarray(a.read(lps)),
                                  np.asarray(payload))
    a.ensure_local(lps)
    assert (a.page_table[lps, 0] == LOCAL).all()
    np.testing.assert_array_equal(np.asarray(a.read(lps)),
                                  np.asarray(payload))


def test_one_collective_per_tier_donor_leg():
    """Each leg of a tier flip is exactly ONE wire message: the domain's
    collective counter and the TransferMeter's priced message counter move
    in lockstep, one per (tier, donor) leg however many pages move."""
    dom = MeshTierDomain()
    a = _tensor(dom)
    lps = a.allocate(6)
    a.write_local(lps, jnp.ones((6,) + a.page_shape, jnp.float32))

    c0, m0 = dom.collectives, a.meter.messages_fabric
    a.offload(lps, prefer=REMOTE)         # push leg: 6 pages, 1 ppermute
    assert dom.collectives - c0 == 1
    assert a.meter.messages_fabric - m0 == 1

    c0, m0 = dom.collectives, a.meter.messages_fabric
    a.ensure_local(lps)                   # pull leg: 6 pages, 1 ppermute
    assert dom.collectives - c0 == 1
    assert a.meter.messages_fabric - m0 == 1


def test_two_donors_one_collective_each():
    dom = MeshTierDomain()
    a = AquaTensor(n_logical=32, page_shape=(4, 6), local_slots=8,
                   host_slots=8, dtype=jnp.float32, meter=TransferMeter(),
                   mesh=dom)
    a.add_remote_lease("d0", 4)
    a.add_remote_lease("d1", 4)
    lps = a.allocate(6)                   # spills across both donor pools
    a.write_local(lps, jnp.full((6,) + a.page_shape, 2.0, jnp.float32))
    c0 = dom.collectives
    a.offload(lps, prefer=REMOTE)
    donors = set(a.page_table[lps, 2].tolist())
    assert donors == {0, 1}               # really split across the peers
    assert dom.collectives - c0 == 2      # one push per donor leg
    c0 = dom.collectives
    a.ensure_local(lps)
    assert dom.collectives - c0 == 2      # one pull per donor leg
    np.testing.assert_array_equal(
        np.asarray(a.read(lps)),
        np.full((6,) + a.page_shape, 2.0, np.float32))


# ---------------------------------------------------------------------------
# mesh vs single-device: bit-identical logits + pool contents per family
# ---------------------------------------------------------------------------
def _roundtrip_logits(cfg, params, prompt, chunks, mesh, decode_steps=2):
    """Chunked prefill + decode, parking REMOTE at every boundary; returns
    the logits arrays and the request's final owned-page payloads."""
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                           mesh=mesh)
    kv.add_remote_lease("d0", 1 << 24)
    pad = kv.pps + 3
    logs = []
    pos = 0
    for c in chunks:
        kv.ensure_capacity(0, pos + c)
        bt = kv.block_tables_prefill(0, pad_to=pad)
        toks = np.zeros((1, bucket_tokens(c)), np.int32)
        toks[0, :c] = prompt[pos:pos + c]
        lg, kv.pools = api.prefill_chunk_paged(
            params, cfg, jnp.asarray(toks), kv.pools, bt,
            jnp.int32(pos), jnp.int32(c - 1), read_pps=kv.pps)
        pos += c
        kv.park(0, pos, prefer=REMOTE)
        kv.restore(0)
    logs.append(np.asarray(lg))
    out = int(np.argmax(logs[-1][0]))
    for t in range(decode_steps):
        ctx = len(prompt) + t + 1
        kv.ensure_capacity(0, ctx)
        bts = kv.block_tables([0, None])
        lg, kv.pools = api.decode_step_paged(
            params, cfg, kv.pools, bts,
            jnp.asarray([out, 0], jnp.int32),
            jnp.asarray([ctx - 1, 0], jnp.int32))
        logs.append(np.asarray(lg[0]))
        out = int(np.argmax(lg[0]))
        kv.park(0, ctx, prefer=REMOTE)
        kv.restore(0)
    pages = {name: np.asarray(pl.aqua.read(
        [lp for row in pl.pages[0] for lp in row]))
        for name, pl in kv.planes.items()}
    return logs, pages


@pytest.mark.parametrize("arch", lm.PAGED_FAMILY_ARCHS)
def test_mesh_matches_single_device_bit_exact(arch):
    """Every family (attention, MLA, hybrid SSM, RWKV6): a run whose pages
    bounce through a REAL peer-device donor slab at every chunk and decode
    boundary produces bit-identical logits AND page payloads to the
    single-device backend."""
    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 17)))
    base_logs, base_pages = _roundtrip_logits(cfg, params, prompt, [7, 10],
                                              None)
    mesh_logs, mesh_pages = _roundtrip_logits(cfg, params, prompt, [7, 10],
                                              MeshTierDomain())
    for a, b in zip(base_logs, mesh_logs):
        np.testing.assert_array_equal(a, b)
    assert set(base_pages) == set(mesh_pages)
    for name in base_pages:
        np.testing.assert_array_equal(base_pages[name], mesh_pages[name],
                                      err_msg=name)


# ---------------------------------------------------------------------------
# donor reclaim mid-flight
# ---------------------------------------------------------------------------
def test_donor_reclaim_mid_flight_evacuates_to_host_bit_exact():
    """The coordinator reclaims the donor while a request is parked on its
    slab: pages evacuate donor -> serving -> host (one pull collective),
    the lease drops, and the restored run continues bit-exact."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 17)))
    base_logs, _ = _roundtrip_logits(cfg, params, prompt, [7, 10], None)

    dom = MeshTierDomain()
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                           mesh=dom)
    kv.add_remote_lease("d0", 1 << 24)
    pad = kv.pps + 3
    pos = 0
    for c in (7, 10):
        kv.ensure_capacity(0, pos + c)
        bt = kv.block_tables_prefill(0, pad_to=pad)
        toks = np.zeros((1, bucket_tokens(c)), np.int32)
        toks[0, :c] = prompt[pos:pos + c]
        lg, kv.pools = api.prefill_chunk_paged(
            params, cfg, jnp.asarray(toks), kv.pools, bt,
            jnp.int32(pos), jnp.int32(c - 1), read_pps=kv.pps)
        pos += c
    kv.park(0, pos, prefer=REMOTE)
    plane = kv.planes["kv"]
    assert (plane.aqua.page_table[:, 0] == REMOTE).any()
    c0 = dom.collectives
    moved = kv.evict_remote("d0")         # mid-flight reclaim
    assert moved > 0
    assert dom.collectives - c0 >= 1      # the evacuation pull really ran
    assert not plane.aqua.remote_pools    # lease dropped
    assert (plane.aqua.page_table[:, 0] != REMOTE).all()
    kv.restore(0)                         # restore now comes from HOST

    out = int(np.argmax(np.asarray(lg)[0]))
    logs = [np.asarray(lg)]
    for t in range(2):
        ctx = len(prompt) + t + 1
        kv.ensure_capacity(0, ctx)
        bts = kv.block_tables([0, None])
        lg, kv.pools = api.decode_step_paged(
            params, cfg, kv.pools, bts,
            jnp.asarray([out, 0], jnp.int32),
            jnp.asarray([ctx - 1, 0], jnp.int32))
        logs.append(np.asarray(lg[0]))
        out = int(np.argmax(lg[0]))
    for a, b in zip(base_logs, logs):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# re-lease bookkeeping (regression: duplicate _donors entries) — no mesh
# ---------------------------------------------------------------------------
def test_donor_re_lease_reuses_bookkeeping_index():
    """An evicted donor that re-leases must reuse its ``_donors`` entry: a
    duplicate append would leave stale donor_idx values resolving to the
    new pool and split one physical donor across two identities."""
    a = AquaTensor(n_logical=16, page_shape=(2, 4), local_slots=8,
                   host_slots=16, dtype=jnp.float32, meter=TransferMeter())
    a.add_remote_lease("d0", 4)
    lps = a.allocate(3)
    payload = jnp.arange(3 * 8, dtype=jnp.float32).reshape((3, 2, 4))
    a.write_local(lps, payload)
    a.offload(lps, prefer=REMOTE)
    assert a.evict_remote("d0") == 3      # all victims captured
    a.add_remote_lease("d0", 4)           # re-lease
    assert a._donors.count("d0") == 1     # no duplicate identity
    a.ensure_local(lps)                   # evacuated pages sit on HOST
    a.offload(lps, prefer=REMOTE)
    assert (a.page_table[lps, 0] == REMOTE).all()
    assert (a.page_table[lps, 2] == a._donors.index("d0")).all()
    np.testing.assert_array_equal(np.asarray(a.read(lps)),
                                  np.asarray(payload))
    # eviction after the re-lease still captures every victim
    assert a.evict_remote("d0") == 3
    np.testing.assert_array_equal(np.asarray(a.read(lps)),
                                  np.asarray(payload))


def test_re_leased_donor_keeps_its_device():
    dom = MeshTierDomain()
    a = _tensor(dom, slots=4)
    dev = dom.donor_device("d0")
    lps = a.allocate(2)
    a.write_local(lps, jnp.ones((2,) + a.page_shape, jnp.float32))
    a.offload(lps, prefer=REMOTE)
    a.evict_remote("d0")
    a.add_remote_lease("d0", 4)
    assert dom.donor_device("d0") == dev  # stable across the reclaim cycle


# ---------------------------------------------------------------------------
# clock calibration
# ---------------------------------------------------------------------------
def test_warm_legs_record_fabric_samples():
    dom = MeshTierDomain()
    a = _tensor(dom)
    lps = a.allocate(4)
    a.write_local(lps, jnp.ones((4,) + a.page_shape, jnp.float32))
    for _ in range(3):                    # same key: first is compile, skipped
        a.offload(lps, prefer=REMOTE)
        a.ensure_local(lps)
    assert len(dom.samples["fabric"]) >= 4
    assert all(b > 0 and t > 0 for b, t in dom.samples["fabric"])


def test_fit_link_model_recovers_known_link():
    alpha, bw = 5e-6, 100e9
    sizes = [1 << 16, 1 << 18, 1 << 20, 1 << 22]
    samples = [(float(s), alpha + s / bw) for s in sizes]
    link = fit_link_model(samples, "fit")
    assert link is not None
    assert link.latency == pytest.approx(alpha, rel=1e-6)
    assert link.peak_bw == pytest.approx(bw, rel=1e-6)
    assert fit_link_model(samples[:1], "fit") is None     # underdetermined
    assert fit_link_model([samples[0]] * 4, "fit") is None


def test_calibrated_profile_replaces_fabric_link():
    dom = MeshTierDomain()
    dom.samples["fabric"] = [(float(s), 1e-5 + s / 50e9)
                             for s in (1 << 14, 1 << 16, 1 << 18, 1 << 20)]
    hw = dom.calibrated_profile(TPU_V5E)
    assert hw is not TPU_V5E
    assert hw.name.endswith("-calibrated")
    assert hw.fabric.peak_bw == pytest.approx(50e9, rel=1e-3)
    # not enough samples -> identity (callers detect no-op with `is`)
    dom2 = MeshTierDomain()
    assert dom2.calibrated_profile(TPU_V5E) is TPU_V5E


def test_engine_calibrate_clock_installs_fitted_profile():
    """``ServingEngine.calibrate_clock`` swaps the measured-fit profile into
    the engine AND the meter, so every subsequent priced flip uses the
    calibrated fabric link; without samples it is a no-op."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    dom = MeshTierDomain()
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64, mesh=dom)
    assert eng.calibrate_clock() is False         # no samples yet
    dom.samples["fabric"] = [(float(s), 2e-5 + s / 25e9)
                             for s in (1 << 14, 1 << 16, 1 << 18, 1 << 20)]
    assert eng.calibrate_clock() is True
    assert eng.hw.name.endswith("-calibrated")
    assert eng.pager.meter.hw is eng.hw
    assert eng.hw.fabric.peak_bw == pytest.approx(25e9, rel=1e-3)
    assert eng.calibrate_clock() is True          # refit stays installable


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------
def test_single_device_domain_rejected():
    with pytest.raises(ValueError, match="2 devices"):
        MeshTierDomain(devices=[jax.devices()[0]])
