"""Request-lifecycle robustness: cancellation out of every state with
auditor-verified page reclamation, TTFT/e2e deadlines on both clocks,
graceful drain/resume, the no-progress watchdog, and crash-consistent
snapshot/restore with bit-identical token completion.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import errors as errs
from repro.core.aqua_tensor import HOST, REMOTE
from repro.core.faults import FaultEvent, FaultInjector, InvariantAuditor
from repro.models import api
from repro.serving.engine import EngineMetrics, ServingEngine
from repro.serving.kv_cache import PagedStateRuntime

ARCH = "qwen1.5-0.5b"


@pytest.fixture(scope="module")
def cfg():
    return smoke_config(get_config(ARCH))


@pytest.fixture(scope="module")
def params(cfg):
    return api.init_params(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, n=4, length=10, seed=0):
    rng = np.random.default_rng(seed)
    return [list(map(int, 1 + rng.integers(0, cfg.vocab_size - 1, length)))
            for _ in range(n)]


def _engine(cfg, params, **kw):
    args = dict(max_running=2, max_seq=64, scheduler="cfs", slice_tokens=4,
                offload_tier=HOST, prefetch=False)
    args.update(kw)
    return ServingEngine(cfg, params, **args)


def _finished_map(eng):
    return {tuple(r.prompt_tokens): r.generated for r in eng.finished
            if r.terminal == "finished"}


def _baseline(cfg, params, prompts, max_new=6, **kw):
    eng = _engine(cfg, params, **kw)
    for p in prompts:
        eng.submit(p, max_new)
    eng.run(500)
    return _finished_map(eng)


# ---------------------------------------------------------------------------
# cancellation: any state, zero leaks, idempotent, typed result path
# ---------------------------------------------------------------------------
def test_cancel_every_state_reclaims_all_pages(cfg, params):
    prompts = _prompts(cfg, n=4)
    # an 8-token step budget over two 10-token prompts lands mid-prefill
    eng = _engine(cfg, params, audit=True, step_tokens=8)
    rs = [eng.submit(p, 6) for p in prompts]
    auditor = InvariantAuditor()

    # waiting: never stepped, holds nothing but (possibly) adopted prefix
    assert rs[3].lifecycle == "waiting"
    assert eng.cancel(rs[3].rid)
    assert auditor.check(eng.kv, engine=eng) == []

    # prefilling: one step in, mid-chunk (10-token prompt, 4-token slices)
    eng.step()
    victim = next(r for r in (rs[0], rs[1]) if r.lifecycle == "prefilling")
    assert eng.cancel(victim.rid)
    assert auditor.check(eng.kv, engine=eng) == []

    # running (decoding): step until a survivor has generated tokens
    survivor = rs[1] if victim is rs[0] else rs[0]
    for _ in range(20):
        if survivor.generated:
            break
        eng.step()
    assert survivor.lifecycle == "running"
    assert eng.cancel(survivor.rid, reason="client")
    assert auditor.check(eng.kv, engine=eng) == []

    # the torn-down rids hold no plane pages and no batch slot
    for r in (rs[3], victim, survivor):
        assert r.terminal == "cancelled" and r.lifecycle == "cancelled"
        assert r.slot is None
        assert all(r.rid not in p.pages for p in eng.kv.planes.values())
        with pytest.raises(errs.CancelledError) as ei:
            eng.output(r.rid)
        assert ei.value.rid == r.rid
        # cancel is idempotent: a second call is a no-op
        assert eng.cancel(r.rid) is False
    assert eng.cancel(10 ** 9) is False          # unknown rid: no-op too

    # the remaining request still completes and the books balance
    m = eng.run(500)
    assert rs[2].terminal == "finished"
    assert m.cancelled == 3 and m.submitted == 4
    assert auditor.check(eng.kv, engine=eng) == []
    assert eng.auditor.audits == m.steps         # audit=True ran every step


def test_cancel_parked_request_mid_offload_pressure(cfg, params):
    """Cancel a request whose pages sit OFF-device (parked to the remote
    tier under page pressure): the release must walk the remote pool's
    refcounts, not just LOCAL."""
    prompts = _prompts(cfg, n=3, length=8, seed=1)
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=1,
                           prefix_sharing=False)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=REMOTE,
                        kv=kv, prefetch=False)
    eng.pager.add_remote_lease("d0", 2 ** 24)
    rs = [eng.submit(p, 6) for p in prompts]
    parked = None
    for _ in range(200):
        eng.step()
        parked = next((r for r in eng.running + eng.waiting if r.parked),
                      None)
        if parked is not None:
            break
    assert parked is not None, "1-page runtime under 2 runners must park"
    auditor = InvariantAuditor()
    assert eng.cancel(parked.rid)
    assert all(parked.rid not in p.pages for p in eng.kv.planes.values())
    assert auditor.check(eng.kv, engine=eng) == []
    eng.run(500)
    assert sum(r.terminal == "finished" for r in eng.finished) == 2


# ---------------------------------------------------------------------------
# deadlines: e2e and TTFT, on the engine clock
# ---------------------------------------------------------------------------
def test_deadline_expiry_sheds_and_counts(cfg, params):
    prompts = _prompts(cfg, n=2, seed=2)
    base = _baseline(cfg, params, prompts)

    eng = _engine(cfg, params)
    r1 = eng.submit(prompts[0], 6, deadline_s=1e-9)      # unmeetable
    r2 = eng.submit(prompts[1], 6, deadline_s=1e9)       # generous
    m = eng.run(500)
    assert r1.terminal == "expired" and r1.cancel_reason == "deadline"
    assert r2.terminal == "finished"
    assert m.deadline_missed == 1 and m.cancelled == 1
    assert all(r1.rid not in p.pages for p in eng.kv.planes.values())
    with pytest.raises(errs.CancelledError):
        eng.output(r1.rid)
    # the survivor's tokens are unaffected by the shed neighbour
    assert r2.generated == base[tuple(prompts[1])]


def test_ttft_deadline_binds_only_until_first_token(cfg, params):
    prompts = _prompts(cfg, n=2, seed=3)
    # cap the step budget so prefill spans steps — the first token cannot
    # land before the sweep has a chance to see the missed deadline
    eng = _engine(cfg, params, step_tokens=8)
    r1 = eng.submit(prompts[0], 6, ttft_deadline_s=1e-9)
    r2 = eng.submit(prompts[1], 6, ttft_deadline_s=1e9)
    m = eng.run(500)
    assert r1.terminal == "expired" and m.deadline_missed == 1
    # a met TTFT deadline never expires the request later in decode
    assert r2.terminal == "finished"


# ---------------------------------------------------------------------------
# graceful drain / resume
# ---------------------------------------------------------------------------
def test_drain_quiesces_and_resume_completes_bit_identically(cfg, params):
    prompts = _prompts(cfg, n=4, seed=4)
    base = _baseline(cfg, params, prompts)

    eng = _engine(cfg, params)
    for p in prompts:
        eng.submit(p, 6)
    for _ in range(3):
        eng.step()
    n = eng.drain()
    assert n >= 1 and eng.metrics.drained == n
    # quiescent: no batch slot held, no active pins, nothing running
    assert not eng.running
    assert not eng.kv._active
    assert all(r.slot is None for r in eng.waiting)
    # a draining engine admits nothing
    steps_before = eng.metrics.steps
    eng.step()
    assert not eng.running and eng.metrics.steps == steps_before + 1
    eng.resume()
    eng.run(500)
    assert _finished_map(eng) == base


# ---------------------------------------------------------------------------
# watchdog: honest starvation is flagged, work still completes
# ---------------------------------------------------------------------------
def test_watchdog_flags_noprogress_requests(cfg, params):
    # 10 requests x 24-token prompts under an 8-token step budget: most of
    # the FCFS batch makes no progress for many consecutive steps
    prompts = _prompts(cfg, n=10, length=24, seed=5)
    eng = _engine(cfg, params, max_running=10, scheduler="fcfs",
                  step_tokens=8, watchdog_steps=5)
    for p in prompts:
        eng.submit(p, 4)
    m = eng.run(2000)
    assert m.watchdog_trips > 0
    assert sum(r.terminal == "finished" for r in eng.finished) == 10


# ---------------------------------------------------------------------------
# crash-consistent snapshot / restore
# ---------------------------------------------------------------------------
def test_snapshot_restore_mid_stream_bit_identical(cfg, params):
    prompts = _prompts(cfg, n=4, seed=6)
    base = _baseline(cfg, params, prompts)

    eng = _engine(cfg, params)
    for p in prompts:
        eng.submit(p, 6)
    for _ in range(3):                           # prefilling + running mix
        eng.step()
    snap = eng.snapshot()

    restored = ServingEngine.restore(cfg, params, snap)
    # audit with a FRESH auditor (the mesh check is stateful per engine)
    assert InvariantAuditor().check(restored.kv, engine=restored) == []
    assert restored.metrics.submitted == 4
    restored.run(500)
    assert _finished_map(restored) == base

    # snapshot is non-destructive: the original keeps serving, identically
    eng.run(500)
    assert _finished_map(eng) == base


def test_snapshot_restore_with_admission_and_prefix_cache(cfg, params):
    shared = list(range(1, 17))                  # two page-aligned pages
    prompts = [shared + t for t in _prompts(cfg, n=3, length=6, seed=7)]
    base = _baseline(cfg, params, prompts, admission=True)

    # stagger the submissions: adoption matches against pages a LIVE
    # request already wrote, so the leader must prefill before followers
    eng = _engine(cfg, params, admission=True)
    eng.submit(prompts[0], 6)
    for _ in range(3):
        eng.step()
    for p in prompts[1:]:
        eng.submit(p, 6)
    for _ in range(2):
        eng.step()
    assert eng.kv.prefix_hits > 0                # sharing actually engaged
    snap = eng.snapshot()
    restored = ServingEngine.restore(cfg, params, snap)
    assert InvariantAuditor().check(restored.kv, engine=restored) == []
    # the admitted set and the radix counters survive the crash boundary
    assert restored.admission._admitted == eng.admission._admitted
    assert restored.kv.prefix_hits == eng.kv.prefix_hits
    assert restored.kv.adopted_tokens == eng.kv.adopted_tokens
    restored.run(500)
    assert _finished_map(restored) == base


def test_engine_crash_fault_is_recoverable(cfg, params):
    prompts = _prompts(cfg, n=3, seed=8)
    base = _baseline(cfg, params, prompts)

    fi = FaultInjector(seed=0, events=[
        FaultEvent(kind="engine_crash", at_step=4)])
    eng = _engine(cfg, params, faults=fi)
    for p in prompts:
        eng.submit(p, 6)
    snap = eng.snapshot()
    with pytest.raises(errs.EngineCrashError):
        for _ in range(500):
            snap = eng.snapshot()                # journal each step boundary
            eng.step()
            if not (eng.waiting or eng.running):
                break
    # crash-consistent restart from the last journal record
    restored = ServingEngine.restore(cfg, params, snap)
    assert InvariantAuditor().check(restored.kv, engine=restored) == []
    restored.run(500)
    assert _finished_map(restored) == base


def test_restore_refuses_a_dirty_runtime(cfg, params):
    eng = _engine(cfg, params)
    eng.submit(_prompts(cfg, n=1, seed=9)[0], 4)
    eng.step()
    snap = eng.snapshot()
    with pytest.raises(ValueError, match="FRESH"):
        eng.kv.restore_state(snap["kv"])         # engine already has pages


# ---------------------------------------------------------------------------
# metrics: explicit right-censoring in the TTFT quantile
# ---------------------------------------------------------------------------
def test_ttft_quantile_censoring():
    m = EngineMetrics()
    assert np.isnan(m.ttft_quantile(0.5))
    m.ttft = {0: 1.0, 1: 2.0, 2: 3.0}
    assert m.ttft_quantile(0.5) == 2.0
    # 3 observed + 3 never-first-token: p99 lands in the censored tail
    assert m.ttft_quantile(0.99, censored=3) == float("inf")
    assert m.ttft_quantile(0.25, censored=3) == 2.0
    # all censored: every quantile is honestly unbounded
    empty = EngineMetrics()
    assert empty.ttft_quantile(0.5, censored=4) == float("inf")


# ---------------------------------------------------------------------------
# seedable abandonment schedules
# ---------------------------------------------------------------------------
def test_make_cancel_events_deterministic_and_sorted():
    from repro.core.workload import make_bursty_requests, make_cancel_events
    reqs = make_bursty_requests(24, seed=1)
    a = make_cancel_events(reqs, frac=0.5, seed=2)
    b = make_cancel_events(reqs, frac=0.5, seed=2)
    assert [(e.rid, e.at_time) for e in a] == [(e.rid, e.at_time) for e in b]
    assert a, "frac=0.5 over 24 requests must select someone"
    c = make_cancel_events(reqs, frac=0.5, seed=3)
    assert [(e.rid, e.at_time) for e in a] != [(e.rid, e.at_time) for e in c]
    assert all(e.kind == "cancel" for e in a)
    assert all(x.at_time <= y.at_time for x, y in zip(a, a[1:]))
    by_rid = {r.rid: r for r in reqs}
    assert all(e.at_time >= by_rid[e.rid].arrival for e in a)
    assert make_cancel_events(reqs, frac=0.0) == []
    with pytest.raises(ValueError):
        make_cancel_events(reqs, frac=1.5)


# ---------------------------------------------------------------------------
# simulator mirror: the same lifecycle on the analytic byte clock
# ---------------------------------------------------------------------------
def _sim(faults=None):
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import ServingSimulator
    scfg = get_config("aqua-codellama-34b")
    wb = scfg.param_count() * 2
    return ServingSimulator(A100_NVLINK, ModelCost.from_config(scfg),
                            weight_bytes=wb,
                            kv_capacity_bytes=80e9 - wb - 2e9,
                            scheduler="cfs", offload_tier="fabric",
                            max_running=4, step_tokens=256, faults=faults)


def _sim_requests(n=12, seed=2, **kw):
    from repro.core.simulator import Request
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / 80.0, n))
    return [Request(i, float(arr[i]), int(rng.integers(300, 800)),
                    int(rng.integers(40, 120)), **kw) for i in range(n)]


def test_simulator_cancel_events_tear_out_the_named_request():
    reqs = _sim_requests()
    fi = FaultInjector(seed=0, events=[
        FaultEvent(kind="cancel", rid=2, at_time=reqs[2].arrival + 0.01),
        FaultEvent(kind="cancel", rid=7, at_time=reqs[7].arrival + 0.01)])
    sim = _sim(faults=fi)
    res = sim.run(reqs)
    assert sim.cancelled == 2
    for r in res.requests:
        if r.rid in (2, 7):
            assert r.cancelled and r.cancel_reason == "fault"
            assert r.finish is None and not r.resident
        else:
            assert r.finish is not None and not r.cancelled


def test_simulator_deadline_sweep_mirrors_the_engine():
    reqs = _sim_requests(seed=5)
    reqs[3].deadline_s = 1e-6                    # unmeetable e2e deadline
    reqs[6].ttft_deadline_s = 1e-6               # unmeetable TTFT deadline
    sim = _sim()
    res = sim.run(reqs)
    assert sim.deadline_missed == 2 and sim.cancelled == 2
    for r in res.requests:
        if r.rid in (3, 6):
            assert r.cancelled and r.cancel_reason == "deadline"
            assert r.finish is None
        else:
            assert r.finish is not None
