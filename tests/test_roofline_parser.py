"""Roofline HLO parser unit tests: trip-count multiplication, dot flops,
collective bytes, in-place-update accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import analyze_text, parse_hlo


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        return jax.lax.scan(body, h, ws)[0]

    h = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    per_dot = 2 * 128 * 128 * 128
    flops = {}
    for L in (4, 16):
        ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        cost = analyze_text(_compile_text(f, h, ws))
        flops[L] = cost.flops
        # one matmul per layer, counted L times (cost_analysis counts once)
        assert cost.flops == pytest.approx(L * per_dot, rel=0.01), L
    assert flops[16] == pytest.approx(4 * flops[4], rel=0.01)


def test_dot_flops_from_contracting_dims():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    cost = analyze_text(_compile_text(f, a, b))
    assert cost.flops == pytest.approx(2 * 64 * 256 * 32, rel=0.01)


def test_inplace_update_counts_slice_not_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 1024), jnp.float32)   # 16 MB
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)      # 4 KB
    # donated buffer -> true in-place update, no input copy
    txt = jax.jit(f, donate_argnums=(0,)).lower(buf, upd).compile().as_text()
    cost = analyze_text(txt)
    # traffic must be ~the update, not the 16 MB buffer
    assert cost.bytes < 1e6


def test_nested_scan_trip_counts_compose():
    def inner(c, x):
        return jnp.tanh(c @ x), None

    def outer(c, xs):
        def ob(c, x):
            return jax.lax.scan(inner, c, x)[0], None
        return jax.lax.scan(ob, c, xs)[0]

    c = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    xs = jax.ShapeDtypeStruct((5, 7, 32, 32), jnp.float32)
    cost = analyze_text(_compile_text(outer, c, xs))
    assert cost.flops == pytest.approx(5 * 7 * 2 * 32 ** 3, rel=0.05)
