"""Pytest config: cap memory on the 1-core CI box.

The suite jit-compiles hundreds of distinct programs (per-arch engines,
kernels in interpret mode, sharded train steps); XLA's in-process executable
cache grows unboundedly and late modules die with LLVM 'Cannot allocate
memory'. Clearing jax caches between modules keeps the peak bounded without
affecting test semantics.
"""
import gc
import os

# Give the suite a multi-device host-platform mesh BEFORE jax initialises:
# the mesh-tier tests (tests/test_mesh_tiers.py) need >= 2 devices so a
# donor lease can live on a real peer device even on the CPU CI box.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
    gc.collect()
