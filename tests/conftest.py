"""Pytest config: cap memory on the 1-core CI box.

The suite jit-compiles hundreds of distinct programs (per-arch engines,
kernels in interpret mode, sharded train steps); XLA's in-process executable
cache grows unboundedly and late modules die with LLVM 'Cannot allocate
memory'. Clearing jax caches between modules keeps the peak bounded without
affecting test semantics.
"""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
    gc.collect()
