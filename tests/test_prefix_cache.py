"""Global radix prefix cache tests: tree-indexed pages outlive refcount 0
in the CACHED state (resident any tier, reclaimable on demand), eviction
yields the cache before any allocation a cache-off run would have served
can fail (LRU, cold-first demotion LOCAL -> REMOTE -> HOST -> free), a
cache hit's decode is BIT-identical to cold prefill, the radix tree splits
on mid-prompt divergence, donor loss drops (never leaks) cached pages, and
the prefix-aware CFS clusters same-group sharers in one plan.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import HOST, LOCAL, REMOTE
from repro.core.faults import InvariantAuditor
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedStateRuntime
from repro.serving.scheduler import CFSScheduler, ReqState, bucket_tokens

ARCH = "qwen1.5-0.5b"
PAD = 11


def _prefill(kv, cfg, params, rid, prompt, chunks, start=0):
    """Chunked prefill directly on the runtime, registering completed
    prefix pages as the engine does. Returns the last chunk's logits."""
    pos = start
    for c in chunks:
        kv.ensure_capacity(rid, pos + c)
        kv.make_writable(rid, pos, pos + c)
        bt = kv.block_tables_prefill(rid, pad_to=PAD)
        toks = np.zeros((1, bucket_tokens(c)), np.int32)
        toks[0, :c] = prompt[pos:pos + c]
        lg, kv.pools = api.prefill_chunk_paged(
            params, cfg, jnp.asarray(toks), kv.pools, bt,
            jnp.int32(pos), jnp.int32(c - 1), read_pps=kv.pps)
        pos += c
        kv.register_prefix(rid, pos)
    return np.asarray(lg)


def _decode(kv, cfg, params, rid, ctx0, first_tok, steps):
    out, logs = first_tok, []
    for t in range(steps):
        ctx = ctx0 + t + 1
        kv.ensure_capacity(rid, ctx)
        kv.make_writable(rid, ctx - 1, ctx)
        bts = kv.block_tables([rid, None])
        lg, kv.pools = api.decode_step_paged(
            params, cfg, kv.pools, bts, jnp.asarray([out, 0], jnp.int32),
            jnp.asarray([ctx - 1, 0], jnp.int32))
        logs.append(np.asarray(lg[0]))
        out = int(np.argmax(lg[0]))
    return logs


@pytest.fixture(scope="module")
def qwen():
    cfg = smoke_config(get_config(ARCH))
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


def _runtime(cfg, **kw):
    args = dict(max_seq=64, page_tokens=8, max_running=2)
    args.update(kw)
    return PagedStateRuntime(cfg, **args)


# ---------------------------------------------------------------------------
# the tentpole invariant: retention past refcount 0, revival on re-adoption
# ---------------------------------------------------------------------------
def test_pages_outlive_refcount_zero_and_revive(qwen):
    """A prefills and releases — its tree-indexed pages stay resident at
    refcount 0 (CACHED) and the next identical prompt revives them: a
    cache HIT, not a live-sharing hit."""
    cfg, params = qwen
    rng = np.random.default_rng(10)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = _runtime(cfg)
    assert kv.sharing and kv.caching
    kv.adopt_prefix(0, prompt)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    plane = kv.planes["kv"]
    cached_lps = [row[0] for row in plane.pages[0]]
    kv.release(0)
    # CACHED: refcount 0, slot kept, payload reachable, index intact
    assert (plane.aqua.refcounts(cached_lps) == 0).all()
    assert (plane.aqua.page_table[cached_lps, 0] != -1).all()
    assert kv.cached_pages()["kv"] == 2 * plane.n_layers
    assert InvariantAuditor().check(kv) == []
    # revival: refcount 0 -> 1, counted as a cache hit
    assert kv.adopt_prefix(1, prompt) == 16
    assert (plane.aqua.refcounts(cached_lps) == 1).all()
    c = kv.stats()["cache"]
    assert c["hits"] == 1 and c["hit_tokens"] == 16
    assert kv.cached_pages()["kv"] == 0
    kv.release(1)
    assert kv.cached_pages()["kv"] == 2 * plane.n_layers


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "deepseek-v2-lite-16b",
                                  "dbrx-132b"])
def test_cache_hit_decode_bit_identical_to_cold_prefill(arch):
    """Per shareable family (GQA kv pages, MLA latent pages, MoE): serving
    a prompt off revived cached pages produces logits BIT-identical to a
    cold prefill on a sharing-off runtime."""
    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(12)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    prompt = prefix + list(map(int, rng.integers(0, cfg.vocab_size, 5)))

    kv0 = _runtime(cfg, prefix_sharing=False)
    lg0 = _prefill(kv0, cfg, params, 0, prompt, [8, 8, 5])
    dec0 = _decode(kv0, cfg, params, 0, len(prompt),
                   int(np.argmax(lg0[0])), 3)

    kv = _runtime(cfg)
    kv.adopt_prefix(0, prefix)
    _prefill(kv, cfg, params, 0, prefix, [8, 8])
    kv.release(0)                                # both prefix pages CACHED
    assert kv.cached_pages()["kv" if "kv" in kv.planes else "mla"] > 0
    assert kv.adopt_prefix(1, prompt) == 16
    assert kv.stats()["cache"]["hits"] == 1
    lg1 = _prefill(kv, cfg, params, 1, prompt, [5], start=16)
    dec1 = _decode(kv, cfg, params, 1, len(prompt),
                   int(np.argmax(lg1[0])), 3)
    np.testing.assert_array_equal(lg0, lg1)
    for a, b in zip(dec0, dec1):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# radix-tree structure: mid-prompt divergence splits the edge
# ---------------------------------------------------------------------------
def test_mid_prompt_divergence_splits_the_edge(qwen):
    """B shares A's first two blocks and diverges in the third: adoption
    reuses the longest common prefix, and registering B SPLITS A's edge at
    the divergence boundary so both suffixes hang off the common node."""
    cfg, params = qwen
    rng = np.random.default_rng(13)
    a = list(map(int, rng.integers(0, cfg.vocab_size, 24)))
    b = a[:16] + [int(t) + 1 for t in a[16:]]    # diverges in block 3
    kv = _runtime(cfg)
    kv.adopt_prefix(0, a)
    # one whole-prompt chunk -> ONE 3-block edge (per-chunk registration
    # would build a chain of 1-block nodes and never need a split)
    _prefill(kv, cfg, params, 0, a, [24])
    root = kv._roots[None]
    assert len(root.children) == 1
    assert len(root.children[tuple(a[:8])].blocks) == 3   # one 3-block edge
    # LCP adoption stops at the divergence boundary (mid-edge)
    assert kv.adopt_prefix(1, b) == 16
    _prefill(kv, cfg, params, 1, b, [8], start=16)
    # the edge split: common 2-block node, two 1-block children
    node = root.children[tuple(a[:8])]
    assert len(node.blocks) == 2
    assert set(node.children) == {tuple(a[16:24]), tuple(b[16:24])}
    assert all(c.parent is node for c in node.children.values())
    assert InvariantAuditor().check(kv) == []
    # release both: the WHOLE tree is cached and both paths stay adoptable
    kv.release(0)
    kv.release(1)
    assert kv.adopt_prefix(2, a) == 24
    assert kv.adopt_prefix(3, b) == 24
    assert kv.stats()["cache"]["hits"] >= 2


def test_lora_id_partitions_the_cache(qwen):
    """Cached pages are only adoptable under the SAME index seed: the same
    tokens under another adapter miss."""
    cfg, params = qwen
    rng = np.random.default_rng(14)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = _runtime(cfg)
    kv.adopt_prefix(0, prompt, seed=7)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    kv.release(0)
    assert kv.cached_pages()["kv"] > 0
    assert kv.adopt_prefix(1, prompt, seed=8) == 0
    assert kv.adopt_prefix(2, prompt, seed=7) == 16
    assert kv.stats()["cache"]["hits"] == 1


def test_cache_revived_sole_referencer_still_copies_on_write(qwen):
    """A revived full-match recompute must clone the shared tail page even
    at refcount 1 — the canonical cached copy stays pristine for the NEXT
    arrival (and the tree keeps pointing at the original)."""
    cfg, params = qwen
    rng = np.random.default_rng(15)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = _runtime(cfg)
    kv.adopt_prefix(0, prompt)
    lga = _prefill(kv, cfg, params, 0, prompt, [8, 8])
    kv.release(0)
    assert kv.adopt_prefix(1, prompt) == 16      # full match, refs 0 -> 1
    n_layers = kv.planes["kv"].n_layers
    lgb = _prefill(kv, cfg, params, 1, prompt, [1], start=15)
    assert kv.cow_copies == n_layers             # cloned despite refs == 1
    np.testing.assert_array_equal(lga, lgb)
    kv.release(1)
    # the canonical copy survived B's recompute: a third twin still hits
    assert kv.adopt_prefix(2, prompt) == 16
    lgc = _prefill(kv, cfg, params, 2, prompt, [1], start=15)
    np.testing.assert_array_equal(lga, lgc)


# ---------------------------------------------------------------------------
# budget integration: eviction yields, LRU order, cold-first demotion
# ---------------------------------------------------------------------------
def test_eviction_yields_cache_before_memory_error(qwen):
    """With every lower tier closed (no host, no lease), LOCAL pressure
    FREES cached blocks instead of raising — a cache-on run never fails an
    allocation a cache-off run would have served."""
    cfg, params = qwen
    rng = np.random.default_rng(16)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = _runtime(cfg, host_pages=0)
    kv.adopt_prefix(0, prompt)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    kv.release(0)
    plane = kv.planes["kv"]
    assert kv.cached_pages()["kv"] == 2 * plane.n_layers
    # exhaust the free list, then allocate past it: cache must yield
    filler = plane.aqua.allocate(plane.aqua.local_free, prefer=LOCAL)
    assert plane.aqua.local_free == 0
    extra = plane.aqua.allocate(1, prefer=LOCAL)
    assert kv.stats()["cache"]["evictions"] >= 1
    plane.aqua.free(list(extra) + list(filler))
    assert InvariantAuditor().check(kv) == []
    # eviction pruned the coverage it dropped: no stale adoption
    matched = kv.adopt_prefix(1, prompt)
    assert matched < 16


def test_lru_evicts_the_coldest_family_first(qwen):
    """Two cached one-block families; the more recently adopted one
    survives LOCAL pressure, the colder one is evicted first."""
    cfg, params = qwen
    rng = np.random.default_rng(17)
    cold = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    warm = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    kv = _runtime(cfg, host_pages=0)
    kv.adopt_prefix(0, cold)
    _prefill(kv, cfg, params, 0, cold, [8])
    kv.release(0)
    kv.adopt_prefix(1, warm)
    _prefill(kv, cfg, params, 1, warm, [8])
    kv.release(1)
    assert kv.adopt_prefix(2, warm) == 8         # bump warm's LRU stamp
    kv.release(2)
    plane = kv.planes["kv"]
    filler = plane.aqua.allocate(plane.aqua.local_free, prefer=LOCAL)
    plane.aqua.free(list(plane.aqua.allocate(1, prefer=LOCAL)))
    plane.aqua.free(filler)
    assert kv.adopt_prefix(3, cold) == 0, "coldest must evict first"
    assert kv.adopt_prefix(4, warm) == 8, "warm family must survive"


def test_cold_first_demotion_keeps_the_block_adoptable(qwen):
    """With host room, LOCAL pressure DEMOTES a cached block down-tier
    instead of dropping it — residence degrades, adoption still hits and
    the restore pays only the page-in."""
    cfg, params = qwen
    rng = np.random.default_rng(18)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = _runtime(cfg, host_pages=64)
    kv.adopt_prefix(0, prompt)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    plane = kv.planes["kv"]
    cached_lps = [lp for row in plane.pages[0] for lp in row]
    kv.release(0)
    filler = plane.aqua.allocate(plane.aqua.local_free, prefer=LOCAL)
    extra = plane.aqua.allocate(1, prefer=LOCAL)
    c = kv.stats()["cache"]
    assert c["demotions"] >= 1 and c["evictions"] == 0
    assert (np.asarray(plane.aqua.page_table[cached_lps, 0]) == HOST).any()
    plane.aqua.free(list(extra) + list(filler))
    assert InvariantAuditor().check(kv) == []
    # the demoted block is still a hit; revival pulls it back LOCAL
    assert kv.adopt_prefix(1, prompt) == 16
    kv.ensure_capacity(1, 16)                    # activates: pages LOCAL
    assert (np.asarray(plane.aqua.page_table[cached_lps, 0]) == LOCAL).all()


def test_admission_capacity_test_still_passes_with_cache_on(qwen):
    """The prefix-cache runtime keeps PR 7's admission win: a LOCAL budget
    sized for one unshared request still runs two sharers concurrently —
    cached pages never shrink what the scheduler can admit."""
    cfg, params = qwen
    rng = np.random.default_rng(19)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, local_pages=27)
    assert kv.caching
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST,
                        kv=kv)
    lead = eng.submit(prefix + [1, 2, 3], 6)
    while not lead.prefilled:
        eng.step()
    eng.submit(prefix + [4, 5, 6], 6)
    peak = 0
    while eng.waiting or eng.running:
        eng.step()
        peak = max(peak, sum(r.slot is not None for r in eng.running))
    assert peak == 2


# ---------------------------------------------------------------------------
# donor loss: cached pages on the dead slab are dropped, never leaked
# ---------------------------------------------------------------------------
def test_donor_loss_drops_cached_pages_and_prunes_the_tree(qwen):
    """CACHED pages parked on a dying donor are dropped with it (their only
    copy died) and their radix coverage pruned — no leak, no dead adoption,
    auditor green."""
    cfg, params = qwen
    rng = np.random.default_rng(20)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = _runtime(cfg)
    plane = kv.planes["kv"]
    kv.add_remote_lease("d0", 64 * plane.aqua.page_bytes)
    kv.adopt_prefix(0, prompt)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    kv.park(0, 16, prefer=REMOTE)                # pages onto the donor slab
    kv.release(0)                                # ...now CACHED on REMOTE
    assert kv.cached_pages()["kv"] == 2 * plane.n_layers
    assert (np.asarray(plane.aqua.page_table[:, 0]) == REMOTE).any()
    victims = kv.fail_donor("d0")
    assert victims == []                         # no live request touched
    assert kv.cached_pages()["kv"] == 0
    assert kv.physical_pages()["kv"] == 1        # scratch only: no leak
    assert kv.adopt_prefix(1, prompt) == 0       # dead prefix unadoptable
    assert InvariantAuditor().check(kv) == []


# ---------------------------------------------------------------------------
# auditor: seeded cache-state corruption is flagged loudly
# ---------------------------------------------------------------------------
def test_auditor_flags_cache_state_corruption(qwen):
    cfg, params = qwen
    rng = np.random.default_rng(21)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = _runtime(cfg)
    kv.adopt_prefix(0, prompt)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    kv.release(0)
    auditor = InvariantAuditor()
    assert auditor.check(kv) == []
    plane = kv.planes["kv"]
    lp = int(plane.pages.get(0, [[0]])[0][0]) if 0 in plane.pages else None
    cached = [i for i in range(len(plane.aqua.page_refs))
              if plane.aqua.page_refs[i] == 0
              and plane.aqua.page_table[i, 0] != -1
              and i != plane.scratch_lp]
    lp = cached[0]
    # a cached page must never be pinned
    plane.pin[lp] = 1
    assert any("pinned" in v for v in auditor.check(kv))
    plane.pin.pop(lp)
    # a cached page outside the radix index is a leak
    entry = kv._lp_node.pop(("kv", lp))
    assert auditor.check(kv)
    kv._lp_node[("kv", lp)] = entry
    assert auditor.check(kv) == []
    # with caching OFF, any refcount-0 resident page is a leak
    kv2 = _runtime(cfg, prefix_cache=False)
    kv2.ensure_capacity(0, 8)
    p2 = kv2.planes["kv"]
    lp2 = int(p2.pages[0][0][0])
    kv2.release(0)
    p2.aqua.page_refs[lp2] = 0
    p2.aqua.page_table[lp2, 0] = 0               # forged resident refs-0 page
    assert any("caching is off" in v for v in auditor.check(kv2))


# ---------------------------------------------------------------------------
# prefix-aware scheduling: same-group requests cluster in one plan
# ---------------------------------------------------------------------------
def test_cfs_clusters_same_prefix_group_within_vruntime_class():
    groups = {0: "g", 1: None, 2: "g", 3: "h"}
    sched = CFSScheduler(4, 3, prefix_group=lambda r: groups.get(r.rid))
    reqs = [ReqState(i, float(i), [1] * 4, 4) for i in range(4)]
    plan = sched.plan(0, reqs, [])
    # rid 2 clusters behind its group anchor rid 0, jumping rid 1
    assert [r.rid for r in plan.run] == [0, 2, 1, 3]
    # fairness first: once the anchor has been served into a higher
    # vruntime class, rid 2 anchors on itself and plain arrival order
    # rules its class — clustering never overrides fairness
    reqs[0].generated = [9, 9]
    plan2 = sched.plan(1, reqs, [])
    assert [r.rid for r in plan2.run] == [1, 2, 3, 0]


def test_engine_coschedules_sharers_parking_the_prefix_once(qwen):
    """Under a budget that fits the sharers only TOGETHER, the prefix-aware
    CFS keeps them in the same plans — the shared prefix never thrashes
    between interleaved singleton plans."""
    cfg, params = qwen
    rng = np.random.default_rng(22)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST)
    assert eng.sched.prefix_group is not None
    lead = eng.submit(prefix + [1, 2], 5)
    while not lead.prefilled:
        eng.step()
    a = eng.submit(prefix + [3, 4], 5)
    b = eng.submit(prefix + [5, 6], 5)
    assert eng.kv.prefix_group_of(a.rid) is eng.kv.prefix_group_of(b.rid)
    eng.run(500)
    assert all(r.done for r in eng.finished) and len(eng.finished) == 3


# ---------------------------------------------------------------------------
# CI smoke: the quickstart workload produces cache hits
# ---------------------------------------------------------------------------
def test_cache_smoke_quickstart_workload(qwen):
    """Quickstart-shaped load, cache flavor: the leader FINISHES before the
    followers arrive, so every follower adoption is a pure cache hit — the
    hit rate on this workload must be nonzero and every follower skips the
    system-prompt prefill."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3,
                        offload_tier=REMOTE)
    eng.pager.add_remote_lease("donor-gpu", 1 << 22)
    rng = np.random.default_rng(1)
    system = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    eng.submit(system + [1, 2], 6)
    eng.run(500)                                 # leader retires fully
    assert not eng.running and not eng.waiting
    assert eng.kv.cached_pages()["kv"] > 0
    followers = [eng.submit(system + list(map(
        int, rng.integers(0, cfg.vocab_size, 4))), 6) for _ in range(3)]
    assert all(f.shared_tokens == 16 for f in followers)
    m = eng.run(500)
    c = eng.kv.stats()["cache"]
    assert c["hits"] >= 1 and c["hit_tokens"] >= 16
    hit_rate = c["hits"] / max(len(followers), 1)
    assert hit_rate > 0
    assert all(len(f.generated) == 6 for f in followers)
    assert m.sim_time > 0
