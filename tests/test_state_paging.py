"""Unified paged state runtime tests: per-family plane layouts, preemption
round-trips that are BIT-identical to unpreempted runs (park mid-prefill and
mid-decode on Mamba/RWKV6/MLA/hybrid state pages), zeroed state-page reuse,
VLM prefix-embeds injection through chunked prefill, and the family-mix
jit-retrace guard (wired into the tier-1 CI workflow).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import HOST, REMOTE
from repro.models import api, lm
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedStateRuntime

FAMILIES = ["qwen1.5-0.5b", "rwkv6-3b", "deepseek-v2-lite-16b",
            "jamba-v0.1-52b"]


# ---------------------------------------------------------------------------
# plane layouts
# ---------------------------------------------------------------------------
def test_paged_layout_planes_per_family():
    expect = {
        "qwen1.5-0.5b": {"kv"},
        "rwkv6-3b": {"wkv", "shift"},
        "deepseek-v2-lite-16b": {"mla"},
        "jamba-v0.1-52b": {"kv", "ssm", "conv"},
        "internvl2-1b": {"kv"},
    }
    for arch, planes in expect.items():
        cfg = smoke_config(get_config(arch))
        layout = api.paged_layout(cfg)
        assert set(layout) == planes, arch
        for spec in layout.values():
            assert spec["kind"] in ("tokens", "state")
    # every sub-layer position is covered exactly once per mixer
    cfg = smoke_config(get_config("jamba-v0.1-52b"))
    layout = api.paged_layout(cfg)
    assert layout["ssm"]["positions"] == layout["conv"]["positions"]
    assert len(layout["kv"]["positions"]) + len(layout["ssm"]["positions"]) \
        == lm.group_size(cfg)


def test_windowed_and_encdec_have_no_layout():
    for arch in ("gemma3-12b", "whisper-tiny"):
        cfg = smoke_config(get_config(arch))
        assert not api.supports_paged(cfg), arch


# ---------------------------------------------------------------------------
# preemption round-trips: bit-identical logits (the tentpole guarantee)
# ---------------------------------------------------------------------------
def _roundtrip_logits(cfg, params, prompt, chunks, park_mid_prefill,
                      park_mid_decode, decode_steps=3):
    """Drive the runtime directly: chunked prefill + decode with optional
    park/restore between every boundary; returns every logits array."""
    from repro.serving.scheduler import bucket_tokens
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    kv.add_remote_lease("d0", 1 << 24)
    pad = kv.pps + 3
    logs = []
    pos = 0
    for c in chunks:
        kv.ensure_capacity(0, pos + c)
        bt = kv.block_tables_prefill(0, pad_to=pad)
        toks = np.zeros((1, bucket_tokens(c)), np.int32)
        toks[0, :c] = prompt[pos:pos + c]
        lg, kv.pools = api.prefill_chunk_paged(
            params, cfg, jnp.asarray(toks), kv.pools, bt,
            jnp.int32(pos), jnp.int32(c - 1), read_pps=kv.pps)
        pos += c
        if park_mid_prefill:
            kv.park(0, pos, prefer=REMOTE)
            kv.restore(0)
    logs.append(np.asarray(lg))
    out = int(np.argmax(logs[-1][0]))
    for t in range(decode_steps):
        ctx = len(prompt) + t + 1
        kv.ensure_capacity(0, ctx)
        bts = kv.block_tables([0, None])
        lg, kv.pools = api.decode_step_paged(
            params, cfg, kv.pools, bts,
            jnp.asarray([out, 0], jnp.int32),
            jnp.asarray([ctx - 1, 0], jnp.int32))
        logs.append(np.asarray(lg[0]))
        out = int(np.argmax(lg[0]))
        if park_mid_decode:
            kv.park(0, ctx, prefer=REMOTE)
            kv.restore(0)
    return logs


@pytest.mark.parametrize("arch", FAMILIES)
def test_preemption_roundtrip_bit_identical(arch):
    """Park mid-prefill AND mid-decode, restore, continue: every logits
    array is bit-identical to an unpreempted run with the same chunk
    schedule — the state pages (KV, MLA latents, ssm/conv, wkv/shift) move
    between tiers byte-exact, with no repack and no dtype roundtrip."""
    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 17)))
    base = _roundtrip_logits(cfg, params, prompt, [7, 10], False, False)
    parked = _roundtrip_logits(cfg, params, prompt, [7, 10], True, True)
    for a, b in zip(base, parked):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_engine_mid_prefill_preemption_state_family_matches_greedy():
    """Engine-level: a tight step budget + CFS rotation parks RWKV6 requests
    mid-prefill (recurrent state pages move, then prefill resumes chunking);
    final tokens match direct greedy."""
    cfg = smoke_config(get_config("rwkv6-3b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (21, 17, 6)]

    def greedy(prompt, n):
        cache = api.init_decode_state(cfg, 1, 64)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache = api.prefill(params, cfg, toks, cache)
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(n - 1):
            pos = jnp.asarray([len(prompt) + len(out) - 1], jnp.int32)
            logits, cache = api.decode_step(
                params, cfg, cache, jnp.asarray([out[-1]], jnp.int32), pos)
            out.append(int(jnp.argmax(logits[0])))
        return out

    truth = [greedy(p, 4) for p in prompts]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=2, offload_tier=HOST,
                        step_tokens=8)
    for p in prompts:
        eng.submit(p, 4)
    m = eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert m.preemptions > 0 and m.prefills > len(prompts)


def test_shared_prefix_pair_roundtrip_moves_bytes_once():
    """Eviction/offload under sharing: a parked shared prefix moves its
    bytes ONCE however many block tables reference it, restore re-links
    both requesters for free once the pages are back, and freeing one
    requester never zeroes pages the other still reads."""
    cfg = smoke_config(get_config("qwen1.5-0.5b")).replace(
        param_dtype="bfloat16", compute_dtype="bfloat16")
    # cache off: this test asserts exact page counts after release, and
    # the global prefix cache would (correctly) retain the registered
    # prefix pages past refcount 0 (covered by tests/test_prefix_cache.py)
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                           prefix_cache=False)
    kv.add_remote_lease("d0", 1 << 24)
    plane = kv.planes["kv"]
    prompt = list(range(100, 116))                    # 2 full pages

    # A writes the prefix and registers it; B adopts every page
    kv.adopt_prefix(0, prompt)
    kv.ensure_capacity(0, 16)
    shared_lps = [lp for row in plane.pages[0] for lp in row]
    rng = np.random.default_rng(0)
    payload = jnp.asarray(
        rng.standard_normal((len(shared_lps),) + plane.aqua.page_shape),
        jnp.bfloat16)
    plane.aqua.write_local(shared_lps, payload)
    kv.register_prefix(0, 16)
    assert kv.adopt_prefix(1, prompt + [7, 8, 9]) == 16
    kv.ensure_capacity(1, 17)                         # B's own tail page

    # park A while B is active: the shared prefix is pinned, ZERO bytes move
    before = kv.meter.bytes_fabric
    kv.park(0, 16, prefer=REMOTE)
    assert kv.meter.bytes_fabric - before == 0.0

    # park B too: the whole physical set moves ONCE (2 shared pages/layer
    # full + B's tail at 1/8 fill), not once per referencer
    before = kv.meter.bytes_fabric
    kv.park(1, 17, prefer=REMOTE)
    n_layers = plane.n_layers
    page_b = plane.aqua.page_bytes
    assert kv.meter.bytes_fabric - before == pytest.approx(
        n_layers * (2 + 1 / 8) * page_b)

    # restore A: moves the shared pages back; restore B then re-links for
    # only its exclusive tail
    before = kv.meter.bytes_fabric
    kv.restore(0)
    assert kv.meter.bytes_fabric - before == pytest.approx(
        n_layers * 2 * page_b)
    before = kv.meter.bytes_fabric
    kv.restore(1)
    assert kv.meter.bytes_fabric - before == pytest.approx(
        n_layers * (1 / 8) * page_b)                  # only the tail's fill

    # freeing one requester never zeroes pages the other still reads
    kv.release(0)
    got = np.asarray(plane.aqua.read(shared_lps), np.float32)
    np.testing.assert_array_equal(
        got, np.asarray(payload, np.float32).astype(np.float32))
    kv.release(1)
    assert kv.physical_pages()["kv"] == 1             # scratch only


def test_state_pages_zeroed_on_slot_reuse():
    """Regression hazard of the unified runtime: a freed state page's LOCAL
    slot still holds the previous occupant's recurrent state; a new request
    allocating that slot must see the zero page (the initial state)."""
    cfg = smoke_config(get_config("rwkv6-3b"))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=1)
    kv.ensure_capacity(0, 4)
    plane = kv.planes["wkv"]
    slots = [plane.aqua.page_table[row[0], 1] for row in plane.pages[0]]
    pool = kv.pools["wkv"]
    kv.pools = {**kv.pools,
                "wkv": pool.at[np.asarray(slots)].set(7.0)}  # decoded state
    kv.release(0)
    kv.ensure_capacity(1, 4)
    new_slots = [plane.aqua.page_table[row[0], 1] for row in plane.pages[1]]
    assert float(jnp.abs(kv.pools["wkv"][np.asarray(new_slots)]).max()) == 0.0


# ---------------------------------------------------------------------------
# VLM prefix embeds (satellite): injected into the q_start==0 chunks
# ---------------------------------------------------------------------------
def test_vlm_prefix_embeds_chunked_prefill_internvl2():
    """internvl2-1b smoke: submit() takes prefix_embeds; the chunked-prefill
    path injects them into the chunks covering positions < n_prefix, and the
    engine's tokens match direct greedy WITH the prefix."""
    cfg = smoke_config(get_config("internvl2-1b"))
    assert cfg.n_prefix_embeds > 0
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    P = cfg.n_prefix_embeds

    def greedy(prompt, pre, n, max_seq=96):
        cache = api.init_decode_state(cfg, 1, max_seq)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache = api.prefill(params, cfg, toks, cache,
                                    prefix_embeds=pre)
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(n - 1):
            pos = jnp.asarray([P + len(prompt) + len(out) - 1], jnp.int32)
            logits, cache = api.decode_step(
                params, cfg, cache, jnp.asarray([out[-1]], jnp.int32), pos)
            out.append(int(jnp.argmax(logits[0])))
        return out

    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (9, 6)]
    pres = [jnp.asarray(rng.standard_normal((1, P, cfg.d_model)) * 0.1,
                        jnp.float32) for _ in prompts]
    truth = [greedy(p, pre, 4) for p, pre in zip(prompts, pres)]
    # step_tokens=8 < P + prompt forces the prefix itself to be chunked
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST,
                        step_tokens=8)
    for p, pre in zip(prompts, pres):
        r = eng.submit(p, 4, prefix_embeds=pre)
        assert r.n_prefix == P and r.prompt_positions == P + len(p)
    m = eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert m.prefills > len(prompts)      # the prefix really was chunked
    # omitting prefix_embeds serves the stub frontend's null image — still
    # transparent vs greedy with the zero prefix
    eng0 = ServingEngine(cfg, params, max_running=2, max_seq=96,
                         scheduler="cfs", slice_tokens=3, offload_tier=HOST,
                         step_tokens=8)
    eng0.submit(prompts[0], 4)            # defaults to the zero prefix
    eng0.run(400)
    zero_truth = greedy(prompts[0],
                        jnp.zeros((1, P, cfg.d_model), jnp.float32), 4)
    assert eng0.finished[0].generated == zero_truth


def test_text_models_reject_prefix_embeds():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_running=1, max_seq=64)
    with pytest.raises(ValueError, match="prefix"):
        eng.submit([1, 2, 3], 2, prefix_embeds=jnp.zeros((1, 4, cfg.d_model)))


# ---------------------------------------------------------------------------
# jit-retrace guard across the family mix (run by the tier-1 CI workflow)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_retrace_guard_trace_count_flat_across_family_mix():
    """Shape buckets make the jit cache independent of the prompt-length mix
    for EVERY family: a second wave of all-new distinct lengths on RWKV6,
    MLA and hybrid engines must add zero traces."""
    rng = np.random.default_rng(6)
    cfgs = {arch: smoke_config(get_config(arch))
            for arch in ("rwkv6-3b", "deepseek-v2-lite-16b",
                         "jamba-v0.1-52b")}
    params = {arch: api.init_params(jax.random.PRNGKey(0), cfg)
              for arch, cfg in cfgs.items()}

    def serve(lengths):
        for arch, cfg in cfgs.items():
            eng = ServingEngine(cfg, params[arch], max_running=2, max_seq=32,
                                scheduler="cfs", slice_tokens=3,
                                offload_tier=HOST, step_tokens=8)
            for n in lengths:
                eng.submit(list(map(int,
                                    rng.integers(0, cfg.vocab_size, n))), 2)
            eng.run(200)

    lm.reset_trace_counts()
    serve([5, 9, 13])
    c1 = lm.trace_counts()
    serve([6, 11, 15])                                # all-new lengths
    c2 = lm.trace_counts()
    # the fused step is the engine's sole entry point: trace count flat
    # across a second wave of all-new distinct lengths, for every family
    assert c2.get("serve_step", 0) == c1.get("serve_step", 0)
    # packed shapes live on the (chunk-bucket x row-bucket) ladder
    assert c2.get("serve_step", 0) <= 8 * len(cfgs)
