"""Burst-stability harness: workload determinism, stability-region
admission safety, occupancy supermartingale, fault composition, and the
prefill progress floor under adversarial mixes.

The properties pinned here are the ones the burst benchmark
(``benchmarks/burst_stability.py``) rests on:

  * a workload trace is a pure function of its seed (bit-identical), so
    benchmark deltas are controller changes, never the generator;
  * the admission controller NEVER admits a candidate whose projected
    occupancy trajectory escapes the stability region (except the
    explicit idle-system progress floor), so a sim run with admission on
    has zero overflow preemptions;
  * under the controller, engine KV occupancy behaves as a
    supermartingale above the headroom line (non-positive empirical
    drift) and never exceeds the budget, with the InvariantAuditor green
    after every step;
  * admission composes with mid-burst fault events: a donor loss shrinks
    the page budget, the controller re-prices against the contracted
    region, and no SchedulingInvariantError escapes;
  * ``split_step_budget`` grants at least one prefill token per step
    even when decode lanes saturate the budget (starvation regression).
"""
from __future__ import annotations

import os
import sys

import numpy as np
import pytest

# the benchmark helpers (codellama_sim, the deprecation re-export) live at
# the repo root, not under src/ — make them importable no matter where
# pytest was launched from
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

from repro.core.errors import AdmissionError
from repro.core.perfmodel import A100_NVLINK
from repro.core.simulator import Request
from repro.core.workload import (BurstSpec, make_bursty_requests,
                                 make_multi_tenant_requests,
                                 prompt_tokens_for, rate_at)
from repro.serving.admission import AdmissionController
from repro.serving.scheduler import split_step_budget

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st


def _trace(reqs):
    return [(r.rid, r.arrival, r.prompt_len, r.gen_len, r.prefix_group,
             r.shared_prefix_len, r.lora_bytes) for r in reqs]


# ---------------------------------------------------------------------------
# workload generator: seed determinism, burst modulation, clamps
# ---------------------------------------------------------------------------
def test_bursty_trace_is_bit_identical_for_same_seed():
    kw = dict(seed=7, base_rate=2.0,
              bursts=[BurstSpec(start=5.0, duration=3.0, factor=10.0)],
              n_tenants=3)
    a = make_bursty_requests(64, **kw)
    b = make_bursty_requests(64, **kw)
    assert _trace(a) == _trace(b)
    c = make_bursty_requests(64, **dict(kw, seed=8))
    assert _trace(a) != _trace(c)


def test_multi_tenant_trace_is_bit_identical_for_same_seed():
    # PR 8 added the generator without a determinism pin — this is it
    a = make_multi_tenant_requests(48, n_tenants=4, seed=3)
    b = make_multi_tenant_requests(48, n_tenants=4, seed=3)
    assert _trace(a) == _trace(b)
    assert _trace(a) != _trace(make_multi_tenant_requests(
        48, n_tenants=4, seed=4))


def test_multi_tenant_reexport_is_the_same_function():
    # benchmarks.common kept the old import path as a deprecation alias
    from benchmarks.common import make_multi_tenant_requests as legacy
    assert legacy is make_multi_tenant_requests


def test_bursty_spike_window_concentrates_arrivals():
    spike = BurstSpec(start=50.0, duration=10.0, factor=10.0)
    reqs = make_bursty_requests(400, seed=0, base_rate=1.0, bursts=[spike])
    in_window = sum(1 for r in reqs
                    if spike.start <= r.arrival < spike.start + spike.duration)
    # 10x modulation: the 10 s window should hold far more than the ~10
    # baseline arrivals (thinning is exact, so ~100 expected)
    assert in_window > 50
    assert rate_at(spike.start, 1.0, [spike]) == 10.0
    assert rate_at(spike.start + spike.duration, 1.0, [spike]) == 1.0


def test_bursty_fields_are_well_formed():
    reqs = make_bursty_requests(128, seed=1, n_tenants=4,
                                max_prompt=2048, max_gen=512)
    assert [r.rid for r in reqs] == list(range(128))
    assert all(reqs[i].arrival <= reqs[i + 1].arrival
               for i in range(len(reqs) - 1))
    for r in reqs:
        assert 1 <= r.prompt_len <= 2048
        assert 1 <= r.gen_len <= 512
        assert r.prefix_group is not None and 0 <= r.prefix_group < 4
        assert 0 < r.shared_prefix_len <= r.prompt_len


def test_prompt_tokens_share_prefix_tokens_within_group():
    reqs = make_bursty_requests(32, seed=2, n_tenants=2)
    toks = prompt_tokens_for(reqs, vocab=97, seed=5)
    again = prompt_tokens_for(reqs, vocab=97, seed=5)
    assert toks == again
    by_group = {}
    for r in reqs:
        by_group.setdefault(r.prefix_group, []).append(r)
    for group, members in by_group.items():
        n = min(m.shared_prefix_len for m in members)
        first = toks[members[0].rid][:n]
        for m in members[1:]:
            assert toks[m.rid][:n] == first
    for r in reqs:
        assert len(toks[r.rid]) == r.prompt_len
        assert all(0 < t < 97 for t in toks[r.rid])


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), factor=st.floats(1.0, 20.0),
       rate=st.floats(0.1, 5.0))
def test_bursty_trace_determinism_property(seed, factor, rate):
    kw = dict(seed=seed, base_rate=rate,
              bursts=[BurstSpec(start=2.0, duration=4.0, factor=factor)],
              n_tenants=2)
    assert _trace(make_bursty_requests(24, **kw)) == \
        _trace(make_bursty_requests(24, **kw))


# ---------------------------------------------------------------------------
# admission controller: config validation + budget-safety property
# ---------------------------------------------------------------------------
def _controller(cap, reqs_by_rid, headroom=0.9, **kw):
    def cost(r, chosen, terminal):
        ctx = r.prompt_len + (r.gen_len if terminal else r.generated)
        return np.array([float(ctx)])
    return AdmissionController(
        budget=lambda: np.array([float(cap)]),
        current_cost=lambda r, c: cost(r, c, False),
        terminal_cost=lambda r, c: cost(r, c, True),
        remaining_tokens=lambda r: (r.prompt_len - r.prefill_pos,
                                    r.gen_len - r.generated),
        headroom=headroom, step_tokens=64, **kw)


def test_admission_controller_validates_config_with_typed_errors():
    with pytest.raises(AdmissionError):
        _controller(100, {}, headroom=0.0)
    with pytest.raises(AdmissionError):
        _controller(100, {}, headroom=1.5)
    with pytest.raises(AdmissionError):
        _controller(100, {}, horizon=0)
    with pytest.raises(AdmissionError):
        _controller(100, {}, prefill_admit_limit=0)


def test_admission_never_exceeds_region_except_progress_floor():
    cap = 1000.0
    reqs = [Request(i, float(i) * 0.01, prompt_len=200, gen_len=150)
            for i in range(12)]
    ctl = _controller(cap, reqs, headroom=0.9, prefill_admit_limit=None)
    eligible, deferred = ctl.filter(reqs, running=[])
    assert eligible and deferred
    floor_rids = set()
    for d in ctl.decisions:
        if d["admitted"]:
            assert d["fits"] and d["mix_ok"]
            assert np.all(d["projected_peak"] <= 0.9 * d["budget"] + 1e-9)
        else:
            floor_rids.add(d["rid"])
    # the progress-floor admission (idle system) is the only way past the
    # region, and it only ever passes the head-of-line candidate
    floored = [r for r in eligible
               if r.rid in floor_rids and r.rid in ctl.admitted_rids]
    assert len(floored) <= 1


def test_admission_progress_floor_prevents_idle_deadlock():
    # a request whose terminal footprint alone exceeds the region must
    # still pass through an idle system (the scheduler's own budget walk
    # decides) instead of deadlocking the engine
    big = Request(0, 0.0, prompt_len=5000, gen_len=5000)
    ctl = _controller(1000.0, {})
    eligible, deferred = ctl.filter([big], running=[])
    assert eligible == [big] and deferred == []


def test_admitted_requests_stay_eligible_and_forget_reprices():
    reqs = [Request(i, float(i), prompt_len=100, gen_len=100)
            for i in range(4)]
    ctl = _controller(1000.0, {}, prefill_admit_limit=None)
    eligible, _ = ctl.filter(reqs, running=[])
    admitted = {r.rid for r in eligible}
    # a preempted-but-admitted request cycling through waiting stays
    # eligible without a fresh stability check
    eligible2, _ = ctl.filter(reqs, running=[])
    assert {r.rid for r in eligible2} >= admitted
    for rid in admitted:
        ctl.forget(rid)
    assert not ctl.admitted_rids


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), cap=st.integers(500, 5000),
       headroom=st.floats(0.5, 1.0))
def test_admission_budget_safety_property(seed, cap, headroom):
    rng = np.random.default_rng(seed)
    reqs = [Request(i, float(rng.uniform(0, 5)),
                    prompt_len=int(rng.integers(10, 400)),
                    gen_len=int(rng.integers(10, 400)))
            for i in range(10)]
    reqs.sort(key=lambda r: r.arrival)
    ctl = _controller(float(cap), {}, headroom=headroom,
                      prefill_admit_limit=None)
    running = []
    ctl.filter(reqs, running)
    for d in ctl.decisions:
        if d["admitted"]:
            assert np.all(d["projected_peak"]
                          <= headroom * d["budget"] + 1e-9)


# ---------------------------------------------------------------------------
# simulator: admission eliminates overflow preemption on the byte clock
# ---------------------------------------------------------------------------
def _overload_sim(admission):
    from benchmarks.common import codellama_sim
    vets = make_bursty_requests(16, seed=0, base_rate=0.5,
                                prompt_median=512, prompt_sigma=0.3,
                                gen_median=6000, gen_sigma=0.2, max_gen=8000)
    spike = make_bursty_requests(10, seed=1, base_rate=2.0,
                                 prompt_median=1024, prompt_sigma=0.3,
                                 gen_median=64, gen_sigma=0.3)
    for r in spike:
        r.arrival += 40.0
    reqs = sorted(vets + spike, key=lambda r: (r.arrival, r.rid))
    for i, r in enumerate(reqs):
        r.rid = i
    sim = codellama_sim(A100_NVLINK, "vllm", "host", step_tokens=256,
                        max_running=32, admission=admission,
                        admission_headroom=0.95, prefill_admit_limit=4)
    sim.run(reqs, horizon=400.0)
    return sim, reqs


def test_admission_on_byte_clock_prevents_overflow_churn():
    off, _ = _overload_sim(False)
    on, on_reqs = _overload_sim(True)
    # the admission-off baseline overshoots capacity and recompute-preempts;
    # terminal-bytes admission never lets the resident set overshoot
    assert off.overflow_swaps > 0
    assert on.overflow_swaps == 0
    assert on.admission.deferred_total > 0       # it actually gated
    assert all(r.finish is not None or r.ttft is not None
               for r in on_reqs if r.arrival < 100.0)


def test_simulator_admission_occupancy_bounded():
    from benchmarks.common import codellama_sim
    reqs = make_bursty_requests(16, seed=3, base_rate=1.0,
                                prompt_median=512, prompt_sigma=0.3,
                                gen_median=2000, gen_sigma=0.2)
    sim = codellama_sim(A100_NVLINK, "vllm", "host", step_tokens=256,
                        max_running=32, admission=True,
                        admission_headroom=0.9)
    res = sim.run(reqs, horizon=600.0)
    assert res.timeline, "sim made no progress"
    for row in res.timeline:
        assert row["occ_frac"] <= 1.0 + 1e-9
        assert row["deferred"] >= 0
    assert sim.overflow_swaps == 0


# ---------------------------------------------------------------------------
# split_step_budget: progress floor under the adversarial mix
# ---------------------------------------------------------------------------
def test_progress_floor_under_saturated_decode_lanes():
    # decode lanes alone eat the whole budget; 10 queued long prefills
    # must still receive exactly one token total (floor), never zero
    chunks = split_step_budget(256, 256, [4096] * 10)
    assert sum(chunks) == 1
    assert max(chunks) == 1
    # over-saturated lanes (more lanes than budget) — same floor
    chunks = split_step_budget(128, 512, [8192] * 10)
    assert sum(chunks) == 1
    # spike arrivals appended mid-burst don't break the floor
    chunks = split_step_budget(64, 64, [2048] * 10 + [512] * 5)
    assert sum(chunks) == 1 and max(chunks) == 1


def test_progress_floor_with_empty_flops_window():
    # roofline window closed (flops_slack=0) + saturated lanes: the floor
    # still grants one token rather than starving the prefill
    chunks = split_step_budget(256, 300, [1024] * 4, flops_slack=0)
    assert sum(chunks) == 1


def test_fair_share_when_budget_available():
    chunks = split_step_budget(256, 16, [4096] * 10)
    assert sum(chunks) == 240
    assert max(chunks) - min(chunks) <= 1     # fair split, spill-over even
    # nobody gets more than their remaining prompt
    chunks = split_step_budget(256, 0, [10, 4096, 3])
    assert chunks[0] <= 10 and chunks[2] <= 3
    assert sum(chunks) <= 256


def test_progress_floor_drains_long_prefill_eventually():
    # iterate the adversarial mix: the head prefill must finish within
    # prompt_len steps even if lanes stay saturated forever
    remaining = [300] + [4096] * 9
    for _ in range(300):
        chunks = split_step_budget(256, 256, remaining)
        remaining = [r - c for r, c in zip(remaining, chunks)]
        if remaining[0] == 0:
            break
    assert remaining[0] == 0


# ---------------------------------------------------------------------------
# engine (real JAX clock): supermartingale occupancy, auditor green,
# fault composition, and the CI burst smoke
# ---------------------------------------------------------------------------
ARCH = "qwen1.5-0.5b"


def _bursty_engine(seed, faults=None, audit=True, n=6, **kw):
    import jax

    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import REMOTE
    from repro.models import api
    from repro.serving.engine import ServingEngine
    from repro.serving.kv_cache import PagedStateRuntime

    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=1,
                           prefix_sharing=False)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=REMOTE,
                        kv=kv, faults=faults, audit=audit, prefetch=False,
                        admission=True, **kw)
    eng.pager.add_remote_lease("d0", 2 ** 24)
    reqs = make_bursty_requests(
        n, seed=seed, base_rate=5.0,
        bursts=[BurstSpec(start=0.0, duration=1.0, factor=5.0)],
        prompt_median=10, prompt_sigma=0.3, gen_median=4, gen_sigma=0.3,
        max_prompt=20, max_gen=6)
    toks = prompt_tokens_for(reqs, vocab=cfg.vocab_size, seed=seed)
    for r in reqs:
        eng.submit(toks[r.rid], max(r.gen_len, 1), arrival=r.arrival)
    return eng


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engine_occupancy_supermartingale_under_admission(seed):
    # 80 rounds per seed with the InvariantAuditor green after every step
    # (audit=True raises InvariantViolation otherwise). Occupancy under
    # the controller must (a) never exceed the page budget and (b) show
    # non-positive empirical drift whenever it sits above the headroom
    # line — the supermartingale property of a stability-region gate.
    eng = _bursty_engine(seed, audit=True)
    eng.run(80)
    occ = eng.metrics.occupancy_trace
    assert occ, "engine made no steps"
    assert max(occ) <= 1.0 + 1e-9
    above = [occ[t + 1] - occ[t] for t in range(len(occ) - 1)
             if occ[t] >= 0.9]
    if above:
        assert sum(above) / len(above) <= 1e-9
    assert eng.metrics.queue_depth_trace  # burst observability populated


def test_engine_admission_composes_with_donor_loss_mid_burst():
    from repro.core.faults import FaultEvent, FaultInjector

    faults = FaultInjector(seed=0, events=[
        FaultEvent(kind="donor_loss", donor="d0", at_step=6)])
    eng = _bursty_engine(3, faults=faults, audit=True)
    cap_before = float(np.sum(eng.kv.total_capacity()))
    budget_before = float(np.sum(np.asarray(eng.admission._budget(),
                                            np.float64)))
    # must not raise SchedulingInvariantError (or anything else): the
    # donor loss contracts total live capacity, _replan_capacity re-plans
    # the stability region (budget = min(LOCAL, total)), and the
    # controller re-prices against whatever the replan leaves standing
    eng.run(400)
    assert float(np.sum(eng.kv.total_capacity())) < cap_before
    budget_after = float(np.sum(np.asarray(eng.admission._budget(),
                                           np.float64)))
    assert budget_after <= budget_before
    assert eng.finished and all(r.done for r in eng.finished)
    assert not eng.running and not eng.waiting


def test_burst_smoke_engine_admission_audit():
    # the CI burst-smoke step: a tiny spike straight through the engine
    # with admission=True, audit=True — metrics populated end to end
    eng = _bursty_engine(0, audit=True, n=4)
    eng.run(200)
    assert not eng.waiting and not eng.running
    m = eng.metrics
    assert len(eng.finished) == 4
    assert m.occupancy_trace and m.queue_depth_trace
    assert np.isfinite(m.ttft_quantile(0.5))
    assert m.ttft_quantile(0.99) >= m.ttft_quantile(0.5)
    assert m.admission_deferrals >= 0
