"""Serving engine tests: transparent AQUA paging is bit-exact on the unified
paged state runtime for EVERY family (attention KV pages, MLA latent pages,
Mamba/RWKV6 state pages), CFS fairness invariants hold, coordinator-driven
elasticity works mid-serve, and the LoRA adapter cache meters coalesced
native-dtype fetches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import HOST, REMOTE
from repro.core.coordinator import Coordinator
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.lora import (AdapterCache, adapter_bytes, apply_lora,
                                init_adapter)
from repro.serving.scheduler import CFSScheduler, FCFSScheduler, ReqState

# families whose decode state is NOT plain paged KV: they exercise the MLA
# latent plane and the Mamba/RWKV6 state planes of the unified runtime
# (qwen, the pure-GQA family, runs the kv plane — see test_paged_runtime.py
# for its deep coverage)
STATE_FAMILIES = ["rwkv6-3b", "deepseek-v2-lite-16b", "jamba-v0.1-52b"]


def _greedy(cfg, params, prompt, n, max_seq=96):
    cache = api.init_decode_state(cfg, 1, max_seq)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = api.prefill(params, cfg, toks, cache)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        pos = jnp.asarray([len(prompt) + len(out) - 1], jnp.int32)
        logits, cache = api.decode_step(params, cfg, cache,
                                        jnp.asarray([out[-1]], jnp.int32), pos)
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("arch", STATE_FAMILIES)
def test_cfs_paging_is_transparent_state_planes(arch):
    """Tokens under CFS + AQUA state-page tier flips == direct greedy: the
    recurrent/latent state round-trips the fabric bit-exactly."""
    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(4, 12)))))
               for _ in range(5)]
    truth = [_greedy(cfg, params, p, 6) for p in prompts]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3, offload_tier=REMOTE)
    eng.pager.add_remote_lease("donor0", 1 << 24)
    for p in prompts:
        eng.submit(p, 6)
    m = eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert m.preemptions > 0 and m.restores > 0
    assert eng.pager.stats()["meter"]["bytes_fabric"] > 0


def test_paged_runtime_serves_pure_attention():
    """The engine serves pure-GQA families page-natively: decode attention
    reads the AquaTensor pool through kernels/paged_attention and preemption
    flips page tiers over the fabric."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(4, 12)))))
               for _ in range(5)]
    truth = [_greedy(cfg, params, p, 6) for p in prompts]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3, offload_tier=REMOTE)
    assert list(eng.kv.planes) == ["kv"] and eng.paged_impl == "pallas"
    eng.pager.add_remote_lease("donor0", 256 * eng.kv.aqua.page_bytes)
    for p in prompts:
        eng.submit(p, 6)
    m = eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert m.preemptions > 0 and m.restores > 0
    assert eng.kv.stats()["meter"]["bytes_fabric"] > 0


def test_unservable_families_rejected_loudly():
    """Families with no page plane yet (windowed ring buffers) are rejected
    at construction — there is no dense fallback runtime anymore."""
    cfg = smoke_config(get_config("gemma3-12b"))
    assert not api.supports_paged(cfg)
    with pytest.raises(ValueError, match="not paged-servable"):
        ServingEngine(cfg, None, max_running=1, max_seq=64)


def test_host_tier_paging_also_transparent():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8))) for _ in range(4)]
    truth = [_greedy(cfg, params, p, 5) for p in prompts]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST)
    for p in prompts:
        eng.submit(p, 5)
    eng.run(300)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert eng.kv.stats()["meter"]["bytes_host"] > 0


def test_cfs_fairness_bounded_fcfs_not():
    """CFS bounds the max-min service spread; FCFS starves late arrivals."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 6))) for _ in range(6)]

    eng_c = ServingEngine(cfg, params, max_running=2, max_seq=96,
                          scheduler="cfs", slice_tokens=2, offload_tier=HOST)
    eng_f = ServingEngine(cfg, params, max_running=2, max_seq=96,
                          scheduler="fcfs", offload_tier=HOST)
    for p in prompts:
        eng_c.submit(p, 12)
        eng_f.submit(p, 12)
    mc = eng_c.run(600)
    mf = eng_f.run(600)
    # CFS: spread bounded by ~slice; FCFS: first admitted finish before others start
    assert max(mc.fairness_trace) <= 2 * 2 + 1
    assert max(mf.fairness_trace) >= 11


def test_elastic_reclaim_mid_serve_preserves_correctness():
    """Donor reclaims its lease while requests' state pages sit on it: pages
    fall back to host, decoding continues bit-exactly (paper §6.2) — the
    evacuation is a page-table retier, no repack."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8))) for _ in range(5)]
    truth = [_greedy(cfg, params, p, 8) for p in prompts]

    coord = Coordinator(strict_pairing=False)
    coord.offer("producer0", 1 << 22)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96, scheduler="cfs",
                        slice_tokens=3, offload_tier=REMOTE,
                        coordinator=coord, name="llm0",
                        want_remote_bytes=1 << 22, respond_every=1)
    for p in prompts:
        eng.submit(p, 8)
    for _ in range(10):
        eng.step()
    coord.request_reclaim("producer0")        # traffic spike on the producer
    eng.run(500)
    assert coord.reclaim_status("producer0")
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert eng.kv.stats()["tiers"]["remote"] == 0


def test_lora_adapter_cache_meters_cold_fetches():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    ad0 = init_adapter(jax.random.PRNGKey(1), cfg, rank=4)
    ad1 = init_adapter(jax.random.PRNGKey(2), cfg, rank=4)
    cache = AdapterCache(capacity_local=1, page_elems=4096, dtype=cfg.dtype())
    cache.put(0, ad0)
    cache.put(1, ad1)
    cache.fetch(0)
    t1 = cache.aqua.meter.sim_time
    cache.fetch(0)                            # hit: free
    assert cache.aqua.meter.sim_time == t1
    cache.fetch(1)                            # cold: metered
    assert cache.aqua.meter.sim_time > t1


def test_lora_adapter_parks_native_dtype_pages():
    """Adapter parking pages the adapter in its NATIVE dtype (one contiguous
    blob, no f32 blowup): the bytes parked equal adapter_bytes up to one
    page of tail padding."""
    cfg = smoke_config(get_config("qwen1.5-0.5b")).replace(
        param_dtype="bfloat16", compute_dtype="bfloat16")
    ad = init_adapter(jax.random.PRNGKey(1), cfg, rank=4)
    cache = AdapterCache(capacity_local=1, page_elems=4096, dtype=cfg.dtype())
    assert cache.aqua.dtype == jnp.bfloat16
    cache.put(0, ad)
    parked = cache.aqua.meter.bytes_fabric + cache.aqua.meter.bytes_host
    page_bytes = cache.aqua.page_bytes
    assert adapter_bytes(ad) <= parked <= adapter_bytes(ad) + page_bytes


def test_apply_lora_changes_only_qv_outputs():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ad = init_adapter(jax.random.PRNGKey(1), cfg, rank=4)
    # B zero-init => identity at init (standard LoRA property)
    p2 = apply_lora(params, cfg, ad)
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    l0, _ = api.model_module(cfg).forward(params, cfg, toks)
    l1, _ = api.model_module(cfg).forward(p2, cfg, toks)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-6)
    # non-zero B changes outputs
    ad2 = dict(ad, q_b=jnp.ones_like(ad["q_b"]) * 0.02)
    p3 = apply_lora(params, cfg, ad2)
    l2, _ = api.model_module(cfg).forward(p3, cfg, toks)
    assert float(jnp.abs(l2 - l0).max()) > 1e-4
