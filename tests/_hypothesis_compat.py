"""Optional-hypothesis shim.

The property tests use ``hypothesis`` when it is installed (declared in
``requirements-dev.txt``); on boxes without it the whole suite must still
*collect* — a hard import here used to kill tier-1 at collection time. The
shim keeps every non-property test running and turns each ``@given`` test
into a single skip.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal CI images
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed "
                                           "(pip install -r requirements-dev.txt)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert stand-in; only ever passed around, never executed."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()
