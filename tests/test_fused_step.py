"""One-launch fused engine steps: the mixed-mode kernel vs its oracle and
the per-mode kernels, bit-identical fused vs per-request paths for every
servable family (across bucket boundaries, mid-page chunk splits, and a
park/restore mid-step round trip), the speculative chunk-ahead satellite,
cross-plane message coalescing, the launch-count model, and the fused-step
jit-retrace guard (trace count flat across request counts — wired into the
tier-1 CI workflow).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import HOST, REMOTE
from repro.kernels.paged_attention.kernel import (
    paged_attention_pool, paged_mixed_attention_pool,
    paged_prefill_attention_pool)
from repro.kernels.paged_attention.ref import paged_mixed_attention_pool_ref
from repro.models import api, lm
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedStateRuntime
from repro.serving.scheduler import bucket_tokens

ARCH = "qwen1.5-0.5b"
FAMILIES = ["qwen1.5-0.5b", "rwkv6-3b", "deepseek-v2-lite-16b",
            "jamba-v0.1-52b"]


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# kernel: mixed-mode fused-pool variant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mixed_kernel_matches_ref(dtype):
    rng = np.random.default_rng(0)
    R, Tc, H, K, hd, P, page, pps = 4, 8, 4, 2, 32, 12, 8, 4
    q = _rand(rng, (R, Tc, H, hd), dtype)
    pool = _rand(rng, (P, 2, K, page, hd), dtype)
    bt = jnp.asarray(rng.integers(0, P, (R, pps)), jnp.int32)
    starts = jnp.asarray([5, 9, 0, 3], jnp.int32)
    n_reals = jnp.asarray([1, 1, 6, 0], jnp.int32)   # 2 decode, chunk, pad
    is_dec = jnp.asarray([1, 1, 0, 0], jnp.int32)
    out = paged_mixed_attention_pool(q, pool, bt, starts, n_reals, is_dec,
                                     interpret=True)
    ref = paged_mixed_attention_pool_ref(q, pool, bt, starts, n_reals,
                                         is_dec)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_mixed_kernel_rows_bit_identical_to_per_mode_kernels():
    """The fused launch's decode rows equal the decode kernel and its chunk
    rows equal the chunk kernel BIT-exactly (garbage rows included — their
    K/V lands in the page window, so the next layer's writes depend on
    them): a row's online-softmax reduction never sees its neighbors."""
    rng = np.random.default_rng(1)
    R, Tc, H, K, hd, P, page, pps = 5, 8, 4, 2, 16, 12, 8, 4
    q = _rand(rng, (R, Tc, H, hd), jnp.float32)
    pool = _rand(rng, (P, 2, K, page, hd), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, (R, pps)), jnp.int32)
    starts = jnp.asarray([5, 9, 21, 3, 11], jnp.int32)
    n_reals = jnp.asarray([1, 1, 1, 6, 8], jnp.int32)
    is_dec = jnp.asarray([1, 1, 1, 0, 0], jnp.int32)
    out = paged_mixed_attention_pool(q, pool, bt, starts, n_reals, is_dec,
                                     interpret=True)
    dec = paged_attention_pool(q[:3, 0], pool, bt[:3], starts[:3] + 1,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(out[:3, 0]), np.asarray(dec))
    ch = paged_prefill_attention_pool(q[3:], pool, bt[3:], starts[3:],
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(out[3:]), np.asarray(ch))


# ---------------------------------------------------------------------------
# fused step == per-request paths, bit-identical, every servable family
# ---------------------------------------------------------------------------
def _prefill_per_request(cfg, params, kv, pad, rid, toks, upto, chunk=8):
    """Drive ``prefill_chunk_paged`` to position ``upto``; returns the last
    chunk's argmax token."""
    pos = 0
    lg = None
    while pos < upto:
        c = min(chunk, upto - pos)
        kv.ensure_capacity(rid, pos + c)
        bt = kv.block_tables_prefill(rid, pad_to=pad)
        tk = np.zeros((1, bucket_tokens(c)), np.int32)
        tk[0, :c] = toks[pos:pos + c]
        lg, kv.pools = api.prefill_chunk_paged(
            params, cfg, jnp.asarray(tk), kv.pools, bt,
            jnp.int32(pos), jnp.int32(c - 1), read_pps=kv.pps)
        pos += c
    return int(np.argmax(np.asarray(lg[0])))


def _fused_vs_per_request(arch, park_mid_step=False):
    """One MIXED step — request 0 decoding, request 1 mid-prefill with a
    bucket-crossing mid-page chunk (6 tokens from position 5), request 2 on
    its first chunk — executed as three per-request calls on runtime A and
    as ONE ``serve_step_paged`` call on runtime B. Logits and every
    request-owned page must be BIT-identical."""
    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    p0 = list(map(int, rng.integers(0, cfg.vocab_size, 11)))
    p1 = list(map(int, rng.integers(0, cfg.vocab_size, 14)))
    p2 = list(map(int, rng.integers(0, cfg.vocab_size, 9)))

    def setup():
        kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8,
                               max_running=3, prefix_sharing=False)
        kv.add_remote_lease("d0", 1 << 24)
        pad = kv.pps + 3
        last = {rid: _prefill_per_request(cfg, params, kv, pad, rid, toks, n)
                for rid, toks, n in ((0, p0, 11), (1, p1, 5))}
        return kv, pad, last

    # --- runtime A: the per-request path (chunks, then batched decode)
    kvA, pad, lastA = setup()
    logits = {}
    for rid, toks, start, c in ((1, p1, 5, 6), (2, p2, 0, 7)):
        kvA.ensure_capacity(rid, start + c)
        bt = kvA.block_tables_prefill(rid, pad_to=pad)
        tk = np.zeros((1, bucket_tokens(c)), np.int32)
        tk[0, :c] = toks[start:start + c]
        lg, kvA.pools = api.prefill_chunk_paged(
            params, cfg, jnp.asarray(tk), kvA.pools, bt,
            jnp.int32(start), jnp.int32(c - 1), read_pps=kvA.pps)
        logits[rid] = np.asarray(lg[0])
    kvA.ensure_capacity(0, 12)
    bts = kvA.block_tables([0, None])
    lg, kvA.pools = api.decode_step_paged(
        params, cfg, kvA.pools, bts,
        jnp.asarray([lastA[0], 0], jnp.int32),
        jnp.asarray([11, 0], jnp.int32))
    logits["dec"] = np.asarray(lg[0])

    # --- runtime B: ONE fused call with the identical packed work
    kvB, pad, lastB = setup()
    assert lastA == lastB
    if park_mid_step:
        for rid, n in ((0, 11), (1, 5)):
            kvB.park(rid, n, prefer=REMOTE)
            kvB.restore(rid)
    for rid, n in ((0, 12), (1, 11), (2, 7)):
        kvB.ensure_capacity(rid, n)
    n_dec, Tc = 2, bucket_tokens(7)
    tokens = np.zeros((4, Tc), np.int32)
    q_starts = np.zeros((4,), np.int32)
    n_reals = np.zeros((4,), np.int32)
    tokens[0, 0] = lastB[0]
    q_starts[0], n_reals[0] = 11, 1                   # decode lane 0
    n_reals[1] = 1                                    # idle decode lane
    tokens[2, :6] = p1[5:11]
    q_starts[2], n_reals[2] = 5, 6                    # mid-page chunk
    tokens[3, :7] = p2[0:7]
    q_starts[3], n_reals[3] = 0, 7                    # first chunk
    bt = kvB.block_tables([0, None, 1, 2], pad_to=pad)
    lg, kvB.pools = api.serve_step_paged(
        params, cfg, jnp.asarray(tokens), kvB.pools, bt,
        jnp.asarray(q_starts), jnp.asarray(n_reals), n_decode=n_dec,
        read_pps=kvB.pps)
    lg = np.asarray(lg)
    np.testing.assert_array_equal(lg[0], logits["dec"])
    np.testing.assert_array_equal(lg[2], logits[1])
    np.testing.assert_array_equal(lg[3], logits[2])
    for name in kvA.planes:
        pa, pb = kvA.planes[name], kvB.planes[name]
        for rid in (0, 1, 2):
            np.testing.assert_array_equal(
                np.asarray(pa.aqua.read(pa.flat(rid))),
                np.asarray(pb.aqua.read(pb.flat(rid))), err_msg=name)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "deepseek-v2-lite-16b"])
def test_fused_step_bit_identical_to_per_request(arch):
    _fused_vs_per_request(arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-v0.1-52b"])
def test_fused_step_bit_identical_to_per_request_state_families(arch):
    _fused_vs_per_request(arch)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "jamba-v0.1-52b"])
def test_fused_step_bit_identical_after_mid_step_park_roundtrip(arch):
    """A park/restore round trip between the per-request prefix and the
    fused step (every plane's pages flip tiers and come back) must not
    perturb a single bit of the fused step's logits or written pages."""
    _fused_vs_per_request(arch, park_mid_step=True)


def test_fused_chunk_splits_bit_identical_across_bucket_boundaries():
    """Prefilling through the fused entry point with chunk splits that
    cross shape buckets and page boundaries ([17] vs [8, 9] vs [16, 1] vs
    [5, 12]) yields BIT-identical final logits — the packed rows inherit
    the chunked pipeline's split invariance."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 17)))

    def last_logits(splits):
        kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                               prefix_sharing=False)
        pad = kv.pps + 3
        pos, out = 0, None
        for c in splits:
            kv.ensure_capacity(0, pos + c)
            Tc = bucket_tokens(c)
            tokens = np.zeros((1, Tc), np.int32)
            tokens[0, :c] = prompt[pos:pos + c]
            bt = kv.block_tables([0], pad_to=pad)
            lg, kv.pools = api.serve_step_paged(
                params, cfg, jnp.asarray(tokens), kv.pools, bt,
                jnp.asarray([pos], jnp.int32), jnp.asarray([c], jnp.int32),
                n_decode=0, read_pps=kv.pps)
            pos += c
            out = np.asarray(lg[0])
        return out

    whole = last_logits([17])
    for splits in ([8, 9], [16, 1], [5, 12], [8, 4, 5]):
        np.testing.assert_array_equal(last_logits(splits), whole)


# ---------------------------------------------------------------------------
# engine: one call per step, launches O(1) in admitted requests
# ---------------------------------------------------------------------------
def test_engine_issues_one_call_per_step_and_matches_greedy():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (19, 11, 26)]

    def greedy(prompt, n):
        cache = api.init_decode_state(cfg, 1, 64)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache = api.prefill(params, cfg, toks, cache)
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(n - 1):
            pos = jnp.asarray([len(prompt) + len(out) - 1], jnp.int32)
            logits, cache = api.decode_step(
                params, cfg, cache, jnp.asarray([out[-1]], jnp.int32), pos)
            out.append(int(jnp.argmax(logits[0])))
        return out

    truth = [greedy(p, 4) for p in prompts]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST,
                        step_tokens=13)
    for p in prompts:
        eng.submit(p, 4)
    m = eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    # launches per step are O(1): one fused call (~n_layers launches)
    # regardless of how many requests' chunks + decode lanes rode the step;
    # the per-request baseline paid one call per chunk row + one for decode
    assert max(m.launch_trace) == cfg.n_layers
    assert max(m.baseline_launch_trace) > cfg.n_layers
    assert m.prefills > len(prompts)                  # chunking really ran


# ---------------------------------------------------------------------------
# speculative chunk-ahead (satellite)
# ---------------------------------------------------------------------------
def test_speculative_chunk_ahead_uses_slack_and_stays_correct():
    """With budget slack (one decode lane, step_tokens 24), the head-of-line
    WAITING prefill is speculatively chunked ahead — its prefill_pos
    advances while it waits, its pages park right after, tokens stay
    greedy-exact, and the final position is never speculated (the first
    token belongs to admission)."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    p_short = list(map(int, rng.integers(0, cfg.vocab_size, 6)))
    p_long = list(map(int, rng.integers(0, cfg.vocab_size, 30)))

    def greedy(prompt, n):
        cache = api.init_decode_state(cfg, 1, 64)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache = api.prefill(params, cfg, toks, cache)
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(n - 1):
            pos = jnp.asarray([len(prompt) + len(out) - 1], jnp.int32)
            logits, cache = api.decode_step(
                params, cfg, cache, jnp.asarray([out[-1]], jnp.int32), pos)
            out.append(int(jnp.argmax(logits[0])))
        return out

    truth = {tuple(p): greedy(p, 4) for p in (p_short, p_long)}

    def serve(spec):
        eng = ServingEngine(cfg, params, max_running=1, max_seq=64,
                            scheduler="fcfs", offload_tier=HOST,
                            step_tokens=24, spec_chunk_ahead=spec,
                            prefetch=False)
        eng.submit(p_short, 4, arrival=0.0)
        eng.submit(p_long, 4, arrival=0.0)
        m = eng.run(400)
        got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
        assert got == truth
        return m, {r.rid: r.ttft_step for r in eng.finished}

    m_off, steps_off = serve(False)
    m_on, steps_on = serve(True)
    assert m_off.spec_chunks == 0
    assert m_on.spec_chunks > 0 and m_on.spec_tokens > 0
    # the speculated prefix shortens the long prompt's admission prefill:
    # its first token lands in an earlier STEP (the smoke model is
    # transfer-bound, so the speculation's priced page flips can outweigh
    # its tiny prefill compute on the wall clock — the time-domain win is
    # asserted at paper scale in the simulator test below)
    assert steps_on[1] < steps_off[1]
    # the token budget still bounds every step (slack was reused, not grown)
    assert max(m_on.prefill_tokens_trace) <= 24


def test_speculative_chunk_ahead_priced_in_simulator():
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import Request, ServingSimulator
    cfg34 = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg34)
    wb = cfg34.param_count() * 2

    def run(spec):
        # FCFS admission: the long prompt sits slot-blocked behind two
        # long decodes — exactly the slack-rich regime speculation targets.
        # A ~96-token budget keeps the speculated chunks under the decode
        # rounds' memory-bound FLOPs slack, so they piggyback nearly free.
        sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                               kv_capacity_bytes=80e9 - wb - 2e9,
                               scheduler="vllm", offload_tier="fabric",
                               max_running=2, step_tokens=96,
                               spec_chunk_ahead=spec)
        reqs = [Request(0, 0.0, 96, 200), Request(1, 0.0, 96, 200),
                Request(2, 0.001, 3000, 20)]
        res = sim.run(reqs)
        return res.requests[2].ttft - res.requests[2].arrival

    # the waiting long prompt's prefill is chunked ahead on decode slack:
    # its first token arrives earlier even though every speculated chunk
    # pays its park/restore page flips
    assert run(True) < run(False) - 0.5


# ---------------------------------------------------------------------------
# cross-plane message coalescing (satellite)
# ---------------------------------------------------------------------------
def test_multi_plane_park_restore_is_one_message_per_tier_donor():
    """A hybrid request's park touches three planes (kv + ssm + conv); the
    fused staging buffer sends ONE fabric message per (tier, donor) — not
    one per plane — and the restore leg matches."""
    cfg = smoke_config(get_config("jamba-v0.1-52b"))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    kv.add_remote_lease("d0", 1 << 24)
    kv.ensure_capacity(0, 17)
    assert len(kv.planes) == 3
    before = kv.meter.messages_fabric
    kv.park(0, 17, prefer=REMOTE)
    assert kv.meter.messages_fabric - before == 1
    before = kv.meter.messages_fabric
    kv.restore(0)
    assert kv.meter.messages_fabric - before == 1
    # bytes are untouched by coalescing: the payload still moves in full
    assert kv.meter.bytes_fabric > 0


def test_plane_coalescing_priced_in_perfmodel_and_simulator():
    from repro.core.perfmodel import A100_NVLINK, ModelCost, page_flip_time
    mc = ModelCost.from_config(get_config("jamba-v0.1-52b"))
    assert mc.n_planes == 3
    assert ModelCost.from_config(get_config("rwkv6-3b")).n_planes == 2
    assert ModelCost.from_config(get_config(ARCH)).n_planes == 1
    nbytes = mc.context_bytes(4096)
    fused = page_flip_time(A100_NVLINK, nbytes, tier="fabric", n_groups=1)
    split = page_flip_time(A100_NVLINK, nbytes, tier="fabric",
                           n_groups=mc.n_planes)
    assert split - fused == pytest.approx(2 * A100_NVLINK.fabric.latency)


# ---------------------------------------------------------------------------
# launch-count model
# ---------------------------------------------------------------------------
def test_launch_overhead_model():
    from repro.core.perfmodel import (A100_NVLINK, ModelCost,
                                      launch_overhead_time)
    assert launch_overhead_time(A100_NVLINK, 0) == 0.0
    assert launch_overhead_time(A100_NVLINK, 96) == \
        pytest.approx(96 * A100_NVLINK.launch_overhead)
    mc = ModelCost.from_config(get_config("aqua-codellama-34b"))
    assert mc.launch_time(A100_NVLINK, 3) == \
        pytest.approx(3 * mc.n_layers * A100_NVLINK.launch_overhead)
    # pod slices dispatch in lockstep: the tax does not shrink with TP
    assert A100_NVLINK.pod_slice(4).launch_overhead == \
        A100_NVLINK.launch_overhead


def test_simulator_fused_step_p99_no_worse_at_scale():
    """34B/A100, 16+ concurrent requests: the fused step's O(1) dispatch
    keeps step-time p99 at or below the per-request baseline, and the gap
    grows with admitted requests (the acceptance criterion)."""
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import Request, ServingSimulator
    cfg34 = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg34)
    wb = cfg34.param_count() * 2

    def run(fused, n):
        sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                               kv_capacity_bytes=80e9 - wb - 2e9,
                               scheduler="cfs", offload_tier="fabric",
                               max_running=n, step_tokens=256,
                               fused_step=fused)
        res = sim.run([Request(i, 0.0005 * i, 800, 40) for i in range(n)])
        steps = np.diff([0.0] + [e["t"] for e in res.timeline])
        return float(np.percentile(steps, 99)), float(res.requests[-1].finish)

    for n in (16, 64):
        p99_f, fin_f = run(True, n)
        p99_b, fin_b = run(False, n)
        assert p99_f <= p99_b
        assert fin_f <= fin_b


# ---------------------------------------------------------------------------
# jit-retrace guard (run explicitly by the tier-1 CI workflow)
# ---------------------------------------------------------------------------
def test_retrace_guard_fused_trace_count_flat_across_request_counts():
    """The packed step's shapes live on the (chunk-bucket x row-bucket)
    ladder with chunk rows capped by the run-set size, so the fused entry
    point's trace count is flat in the number of admitted requests: serving
    8x more requests (with all-new prompt lengths) adds ZERO traces."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)

    def serve(n_requests):
        eng = ServingEngine(cfg, params, max_running=4, max_seq=64,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=HOST, step_tokens=16)
        for i in range(n_requests):
            n = int(rng.integers(4, 30))
            eng.submit(list(map(int, rng.integers(0, cfg.vocab_size, n))), 2)
        eng.run(1200)
        assert len(eng.finished) == n_requests

    lm.reset_trace_counts()
    serve(8)                       # saturates the slot cap + spec row
    c1 = lm.trace_counts().get("serve_step", 0)
    serve(64)                      # 8x the requests, all-new lengths
    c2 = lm.trace_counts().get("serve_step", 0)
    assert c2 == c1
    assert c1 <= 10                # the bucket ladder, not the workload
