"""Docs-health gate in tier-1: README.md and docs/*.md must exist, every
fenced python block must compile and import cleanly against src/, and every
intra-repo link must resolve (the same check CI runs via
scripts/check_docs.py)."""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_docs_exist():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "architecture.md").exists()
    assert (ROOT / "docs" / "paged_runtime.md").exists()


def test_docs_health_checker_passes():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_docs.py")],
        capture_output=True, text=True, env=env, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_docs_health_checker_catches_breakage(tmp_path):
    """The checker is not vacuous: a broken link and a bad import fail."""
    sys.path.insert(0, str(ROOT / "scripts"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    bad = tmp_path / "bad.md"
    bad.write_text("[x](nope/missing.md)\n\n```python\n"
                   "import repro.module_that_never_existed\n```\n")
    errors = check_docs.check_file(bad)
    assert len(errors) == 2
    assert any("broken link" in e for e in errors)
    assert any("import failed" in e for e in errors)
