"""Copy-on-write prefix sharing tests: adopted block tables alias physical
pages (strictly fewer physical pages than unshared, bit-identical logits),
copy-on-write isolates a sharer's writes, refcounts keep pages alive until
the last referencer frees them, and the schedulers admit strictly larger run
sets because they budget PHYSICAL pages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import HOST, AquaTensor
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedStateRuntime
from repro.serving.scheduler import bucket_tokens

ARCH = "qwen1.5-0.5b"
PAD = 11                                  # pps(8) + chunk window spill


def _prefill(kv, cfg, params, rid, prompt, chunks, start=0):
    """Drive chunked prefill for one request directly on the runtime,
    registering completed prefix pages as the engine does. Returns the last
    chunk's logits."""
    pos = start
    for c in chunks:
        kv.ensure_capacity(rid, pos + c)
        kv.make_writable(rid, pos, pos + c)
        bt = kv.block_tables_prefill(rid, pad_to=PAD)
        toks = np.zeros((1, bucket_tokens(c)), np.int32)
        toks[0, :c] = prompt[pos:pos + c]
        lg, kv.pools = api.prefill_chunk_paged(
            params, cfg, jnp.asarray(toks), kv.pools, bt,
            jnp.int32(pos), jnp.int32(c - 1), read_pps=kv.pps)
        pos += c
        kv.register_prefix(rid, pos)
    return np.asarray(lg)


def _decode(kv, cfg, params, rid, ctx0, first_tok, steps):
    """Greedy-decode `steps` tokens for one request; returns logits arrays."""
    out, logs = first_tok, []
    for t in range(steps):
        ctx = ctx0 + t + 1
        kv.ensure_capacity(rid, ctx)
        kv.make_writable(rid, ctx - 1, ctx)
        bts = kv.block_tables([rid, None])
        lg, kv.pools = api.decode_step_paged(
            params, cfg, kv.pools, bts, jnp.asarray([out, 0], jnp.int32),
            jnp.asarray([ctx - 1, 0], jnp.int32))
        logs.append(np.asarray(lg[0]))
        out = int(np.argmax(lg[0]))
    return logs


@pytest.fixture(scope="module")
def qwen():
    cfg = smoke_config(get_config(ARCH))
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# the acceptance invariant: fewer physical pages, bit-identical logits
# ---------------------------------------------------------------------------
def test_shared_prefix_fewer_physical_pages_bit_identical_logits(qwen):
    """Two requests with an identical 2-page prompt prefix occupy strictly
    fewer physical pages than 2x one request, and the sharer's prefill +
    decode logits are BIT-identical to unshared execution."""
    cfg, params = qwen
    rng = np.random.default_rng(0)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 16)))  # 2 pages
    b_prompt = prefix + list(map(int, rng.integers(0, cfg.vocab_size, 5)))

    # unshared truth: B alone on a sharing-disabled runtime
    kv0 = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                            prefix_sharing=False)
    lg0 = _prefill(kv0, cfg, params, 0, b_prompt, [8, 8, 5])
    solo_pages = kv0.physical_pages()["kv"]
    dec0 = _decode(kv0, cfg, params, 0, len(b_prompt),
                   int(np.argmax(lg0[0])), 3)

    # shared: A writes the prefix, B adopts it
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    assert kv.sharing
    assert kv.adopt_prefix(0, prefix) == 0        # empty index
    _prefill(kv, cfg, params, 0, prefix, [8, 8])
    matched = kv.adopt_prefix(1, b_prompt)
    assert matched == 16                          # both full prefix pages
    lg1 = _prefill(kv, cfg, params, 1, b_prompt, [5], start=matched)
    dec1 = _decode(kv, cfg, params, 1, len(b_prompt),
                   int(np.argmax(lg1[0])), 3)

    np.testing.assert_array_equal(lg0, lg1)       # first-token logits
    for a, b in zip(dec0, dec1):                  # decode logits
        np.testing.assert_array_equal(a, b)
    # A(2 pages) + B(2 shared + 1 own) per layer < A + B unshared
    both = kv.physical_pages()["kv"]
    assert both < solo_pages + kv.physical_pages()["kv"] // 1  # sanity
    assert both < 2 * solo_pages
    assert sum(kv.logical_pages().values()) > both  # tables alias pages
    assert kv.stats()["sharing"]["prefix_hits"] == 1


def test_full_match_copy_on_write_isolates_the_sharer(qwen):
    """B's prompt IS A's prompt (fully page-aligned): B adopts every page,
    recomputes only the final position — the write clones the shared tail
    page (one CoW per layer row) and A's subsequent decode is unaffected."""
    cfg, params = qwen
    rng = np.random.default_rng(1)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))

    # solo truth for both sides
    kv0 = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                            prefix_sharing=False)
    lg0 = _prefill(kv0, cfg, params, 0, prompt, [8, 8])
    dec0 = _decode(kv0, cfg, params, 0, len(prompt),
                   int(np.argmax(lg0[0])), 3)

    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    kv.adopt_prefix(0, prompt)
    lga = _prefill(kv, cfg, params, 0, prompt, [8, 8])
    assert kv.adopt_prefix(1, prompt) == 16
    n_layers = kv.planes["kv"].n_layers
    # the recompute chunk starts at the last position and CoWs its page
    lgb = _prefill(kv, cfg, params, 1, prompt, [1], start=15)
    assert kv.cow_copies == n_layers
    np.testing.assert_array_equal(lga, lgb)
    # after CoW the tail page is exclusive again; the first page stays shared
    plane = kv.planes["kv"]
    assert int(plane.aqua.refcounts([plane.pages[1][0][1]])[0]) == 1
    assert int(plane.aqua.refcounts([plane.pages[1][0][0]])[0]) == 2
    # B's recompute/decode writes never corrupt A: A decodes bit-identically
    decb = _decode(kv, cfg, params, 1, len(prompt), int(np.argmax(lgb[0])), 3)
    deca = _decode(kv, cfg, params, 0, len(prompt), int(np.argmax(lga[0])), 3)
    for a, b in zip(dec0, deca):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(dec0, decb):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# refcount lifecycle
# ---------------------------------------------------------------------------
def test_refcounted_free_keeps_shared_pages_alive():
    """AquaTensor refcounts: freeing one referencer neither releases the
    physical slot nor touches the payload; the last free does both."""
    t = AquaTensor(n_logical=16, page_shape=(4,), local_slots=8, host_slots=4,
                   dtype=jnp.float32, name="shared")
    lps = t.allocate(2)
    t.write_local(lps, jnp.arange(8, dtype=jnp.float32).reshape(2, 4))
    t.retain(lps)                                # second block table
    assert (t.refcounts(lps) == 2).all()
    assert t.free(lps) == []                     # first free: deref only
    assert (t.page_table[lps, 0] != -1).all()
    np.testing.assert_array_equal(np.asarray(t.read(lps)).ravel(),
                                  np.arange(8, dtype=np.float32))
    assert t.local_free == 8 - 2                 # slots still occupied
    assert sorted(t.free(lps)) == sorted(int(l) for l in lps)
    assert t.local_free == 8
    with pytest.raises(ValueError, match="retain"):
        t.retain(lps)                            # dead pages can't be shared


def test_release_of_one_requester_preserves_the_others_pages(qwen):
    """Runtime-level: A registers, B adopts, A releases mid-flight — B's
    shared pages survive (never zeroed/reused) and the index entries backed
    by them stay valid until B too is gone. With ``prefix_cache=False`` the
    LAST release drops the index (the pre-cache lifecycle; retention past
    refcount 0 is covered in test_prefix_cache.py)."""
    cfg, params = qwen
    rng = np.random.default_rng(2)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2,
                           prefix_cache=False)
    assert kv.sharing and not kv.caching
    kv.adopt_prefix(0, prompt)
    lg = _prefill(kv, cfg, params, 0, prompt, [8, 8])
    assert kv.adopt_prefix(1, prompt + [3, 4]) == 16
    plane = kv.planes["kv"]
    shared_lps = [row[0] for row in plane.pages[1]]
    payload = np.asarray(plane.aqua.read(shared_lps))
    kv.release(0)
    # B still owns the pages: allocated, payload untouched
    assert (plane.aqua.page_table[shared_lps, 0] != -1).all()
    assert (plane.aqua.refcounts(shared_lps) == 1).all()
    np.testing.assert_array_equal(np.asarray(plane.aqua.read(shared_lps)),
                                  payload)
    # a third twin can still adopt from B's live pages
    assert kv.adopt_prefix(2, prompt) == 16
    kv.release(2)
    kv.release(1)
    # last release drops the index too: nothing left to adopt
    assert kv.adopt_prefix(3, prompt) == 0
    assert kv.physical_pages()["kv"] == 1         # only the scratch page


# ---------------------------------------------------------------------------
# schedulers budget physical pages
# ---------------------------------------------------------------------------
def test_marginal_page_cost_discounts_shared_pages(qwen):
    """The engine's CFS page cost is MARGINAL: a request whose prefix pages
    are already counted by a chosen sharer costs only its exclusive pages."""
    cfg, params = qwen
    rng = np.random.default_rng(3)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST)
    a = eng.submit(prefix + [1, 2, 3], 4)
    while not a.prefilled:
        eng.step()
    b = eng.submit(prefix + [4, 5, 6], 4)
    assert b.shared_tokens == 16 and b.prefill_pos == 16
    alone = eng._page_cost_cfs(b, [])
    with_a = eng._page_cost_cfs(b, [a])
    n_layers = eng.kv.planes["kv"].n_layers
    assert (alone - with_a == 2 * n_layers).all()   # both prefix pages


def test_shared_prefix_raises_admission_capacity(qwen):
    """A LOCAL budget too small for two unshared requests runs both sharers
    CONCURRENTLY when they alias a prefix: physical-page budgeting admits
    the pair, and the generated tokens still match the unshared run."""
    cfg, params = qwen
    rng = np.random.default_rng(4)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    tails = [list(map(int, rng.integers(0, cfg.vocab_size, 4)))
             for _ in range(2)]

    def serve(sharing):
        kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8,
                               local_pages=27, prefix_sharing=sharing)
        eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=HOST, kv=kv)
        lead = eng.submit(prefix + tails[0], 6)
        while not lead.prefilled:
            eng.step()
        eng.submit(prefix + tails[1], 6)
        peak = 0
        while eng.waiting or eng.running:
            eng.step()
            peak = max(peak, sum(r.slot is not None for r in eng.running))
        toks = [r.generated for r in sorted(eng.finished,
                                            key=lambda r: r.rid)]
        return toks, peak

    toks_s, peak_s = serve(True)
    toks_u, peak_u = serve(False)
    assert toks_s == toks_u
    assert peak_s == 2, "sharers must fit the LOCAL budget together"
    assert peak_u == 1, "unshared pair must not fit (budget sized for it)"


# ---------------------------------------------------------------------------
# families / modes that must opt out
# ---------------------------------------------------------------------------
def test_recurrent_state_families_disable_sharing():
    """A recurrent state page summarizes the whole prefix and is rewritten
    every step — families owning one never share (the layout marks their
    planes non-shareable)."""
    for arch in ("rwkv6-3b", "jamba-v0.1-52b"):
        cfg = smoke_config(get_config(arch))
        layout = api.paged_layout(cfg)
        assert not all(s.get("shareable", False) for s in layout.values())
        kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8)
        assert not kv.sharing
        assert kv.adopt_prefix(0, list(range(24))) == 0


def test_forged_radix_collision_never_aliases_foreign_pages(qwen):
    """Radix children are keyed by their first token block and the walk
    compares edge blocks verbatim: a forged key collision (another prompt's
    block mapped onto this node) yields a miss, never foreign pages."""
    cfg, params = qwen
    rng = np.random.default_rng(6)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    kv.adopt_prefix(0, prompt)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    other = [t + 1 for t in prompt]
    root = kv._roots[None]
    node = root.children[tuple(prompt[:8])]
    root.children[tuple(other[:8])] = node    # forged hash collision
    assert kv.adopt_prefix(1, other) == 0     # token mismatch -> miss
    assert kv.adopt_prefix(2, prompt) == 16   # honest match still works
    del root.children[tuple(other[:8])]


def test_lora_id_partitions_the_prefix_index(qwen):
    """The same tokens under a different adapter produce different K/V: the
    index never aliases across lora ids (hash seed)."""
    cfg, params = qwen
    rng = np.random.default_rng(5)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=2)
    kv.adopt_prefix(0, prompt, seed=7)
    _prefill(kv, cfg, params, 0, prompt, [8, 8])
    assert kv.adopt_prefix(1, prompt, seed=8) == 0      # other adapter
    assert kv.adopt_prefix(2, prompt, seed=7) == 16     # same adapter
