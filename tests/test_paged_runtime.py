"""Page-native serving runtime tests (kv plane deep coverage): fused-pool
kernels vs oracles, batched block-table queries, partial-tail metering,
tier-exhaustion errors, bit-identical decoding under CFS preemption in bf16,
unified TTFT accounting, and the context-switch microbenchmark's coalescing
invariants. The other planes (mla/ssm/conv/wkv/shift) are covered in
tests/test_state_paging.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import HOST, LOCAL, REMOTE, AquaTensor
from repro.kernels.paged_attention.kernel import (append_kv,
                                                  paged_attention_pool)
from repro.kernels.paged_attention.ref import (append_kv_ref,
                                               paged_attention_pool_ref)
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedStateRuntime

ARCH = "qwen1.5-0.5b"


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# kernels: fused page-major pool variant + page-append writer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,hd,P,page,pps", [
    (2, 4, 2, 64, 16, 8, 4),
    (3, 6, 2, 32, 32, 16, 6),
    (4, 8, 1, 64, 64, 32, 4),               # MQA
])
def test_paged_attention_pool_matches_ref(B, H, K, hd, P, page, pps, dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (B, H, hd), dtype)
    pool = _rand(rng, (P, 2, K, page, hd), dtype)
    bt = jnp.asarray(rng.integers(0, P, (B, pps)), jnp.int32)
    ln = jnp.asarray(rng.integers(1, pps * page + 1, (B,)), jnp.int32)
    out = paged_attention_pool(q, pool, bt, ln, interpret=True)
    ref = paged_attention_pool_ref(q, pool, bt, ln)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_append_kv_writes_one_row_per_sequence(dtype):
    rng = np.random.default_rng(1)
    B, K, hd, P, page = 3, 2, 32, 8, 8
    pool = _rand(rng, (P, 2, K, page, hd), dtype)
    k_new = _rand(rng, (B, K, hd), dtype)
    v_new = _rand(rng, (B, K, hd), dtype)
    slots = jnp.asarray([1, 4, 6], jnp.int32)
    offs = jnp.asarray([0, 3, 7], jnp.int32)
    out = append_kv(pool, k_new, v_new, slots, offs, interpret=True)
    ref = append_kv_ref(pool, k_new, v_new, slots, offs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # untouched pages bit-identical
    untouched = np.setdiff1d(np.arange(P), np.asarray(slots))
    np.testing.assert_array_equal(np.asarray(out[untouched]),
                                  np.asarray(pool[untouched]))


def test_append_then_attend_equals_contiguous():
    """Pages filled token-by-token through the writer op attend identically
    to contiguous attention."""
    from repro.kernels.flash_attention.ref import flash_attention_ref
    rng = np.random.default_rng(2)
    K, hd, page, pps = 2, 32, 4, 3
    S = page * pps
    H = 4
    kc = _rand(rng, (1, S, K, hd), jnp.float32)
    vc = _rand(rng, (1, S, K, hd), jnp.float32)
    pool = jnp.zeros((pps + 1, 2, K, page, hd), jnp.float32)
    bt = jnp.asarray([[1, 2, 3]], jnp.int32)        # slot 0 = scratch
    for t in range(S):
        slot = bt[0, t // page][None]
        off = jnp.asarray([t % page], jnp.int32)
        pool = append_kv(pool, kc[:, t], vc[:, t], slot, off, interpret=True)
    q = _rand(rng, (1, 1, H, hd), jnp.float32)
    ref = flash_attention_ref(q, kc, vc, causal=True)[:, 0]
    out = paged_attention_pool(q[:, 0], pool, bt,
                               jnp.asarray([S], jnp.int32), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ---------------------------------------------------------------------------
# AquaTensor: batched block tables, partial tails, tier exhaustion
# ---------------------------------------------------------------------------
def test_block_tables_batched_query_and_padding():
    t = AquaTensor(n_logical=32, page_shape=(4,), local_slots=16,
                   host_slots=8, dtype=jnp.float32)
    a = t.allocate(3)
    b = t.allocate(2)
    bt = t.block_tables([list(a), list(b), []], pad_to=4, pad_slot=9)
    assert bt.shape == (3, 4) and bt.dtype == np.int32
    np.testing.assert_array_equal(bt[0, :3], t.page_table[a, 1])
    assert (bt[0, 3:] == 9).all() and (bt[2] == 9).all()
    # non-LOCAL pages are rejected: the caller must ensure_local first
    t.offload(a[:1], prefer=HOST)
    with pytest.raises(ValueError, match="not LOCAL"):
        t.block_tables([list(a)], pad_to=4)


def test_partial_tail_pages_metered_at_fill():
    t = AquaTensor(n_logical=16, page_shape=(8,), local_slots=8,
                   host_slots=16, dtype=jnp.bfloat16)
    lps = t.allocate(4)
    t.write_local(lps, jnp.ones((4, 8), jnp.bfloat16))
    t.set_page_fill(lps[-1:], 0.5)                  # half-filled tail
    t.offload(lps, prefer=HOST)
    assert t.meter.bytes_host == 3.5 * t.page_bytes
    assert t.meter.messages_host == 1               # one coalesced message


def test_move_to_full_tier_raises_memoryerror_not_indexerror():
    """Regression: host-tier exhaustion during migration used to surface as a
    bare IndexError from list.pop on the empty free list."""
    t = AquaTensor(n_logical=16, page_shape=(4,), local_slots=8, host_slots=2,
                   dtype=jnp.float32, name="kvtest")
    lps = t.allocate(4)
    t.write_local(lps, jnp.ones((4, 4), jnp.float32))
    with pytest.raises(MemoryError, match="kvtest.*host"):
        t.offload(lps, prefer=HOST)


def test_evict_remote_onto_full_host_raises_memoryerror():
    t = AquaTensor(n_logical=16, page_shape=(4,), local_slots=8, host_slots=1,
                   dtype=jnp.float32, name="kvtest")
    t.add_remote_lease("d0", 8)
    lps = t.allocate(3)
    t.write_local(lps, jnp.ones((3, 4), jnp.float32))
    t.offload(lps, prefer=REMOTE)
    with pytest.raises(MemoryError, match="kvtest.*host"):
        t.evict_remote("d0")


# ---------------------------------------------------------------------------
# engine: paged runtime end-to-end
# ---------------------------------------------------------------------------
def _greedy(cfg, params, prompt, n, max_seq=64):
    cache = api.init_decode_state(cfg, 1, max_seq)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = api.prefill(params, cfg, toks, cache)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        pos = jnp.asarray([len(prompt) + len(out) - 1], jnp.int32)
        logits, cache = api.decode_step(params, cfg, cache,
                                        jnp.asarray([out[-1]], jnp.int32), pos)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_preemption_bit_identical_bf16_no_f32_roundtrip():
    """Tentpole parity: prefill + decode with interleaved CFS preemptions
    produces bit-identical tokens vs serving each request alone (never
    preempted) — in bf16, with NO float32 roundtrip on the context switches:
    park/restore move the native-dtype page payloads untouched."""
    cfg = smoke_config(get_config(ARCH)).replace(param_dtype="bfloat16",
                                                 compute_dtype="bfloat16")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(4, 12)))))
               for _ in range(4)]

    def serve(batch):
        eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=REMOTE)
        eng.pager.add_remote_lease("donor0", 2 ** 24)
        if batch:                              # contended: CFS preempts
            for p in prompts:
                eng.submit(p, 6)
            m = eng.run(400)
            assert m.preemptions > 0 and m.restores > 0
        else:                                  # serial: never preempted
            for p in prompts:
                eng.submit(p, 6)
                eng.run(400)
            assert eng.metrics.preemptions == 0
        return {tuple(r.prompt_tokens): r.generated for r in eng.finished}, eng

    got_preempted, eng_p = serve(True)
    got_serial, _ = serve(False)
    assert got_preempted == got_serial
    # the paged switches moved native-dtype pages over the fabric
    assert eng_p.kv.meter.bytes_fabric > 0
    assert eng_p.kv.aqua.dtype == jnp.bfloat16


def test_paged_engine_transparent_vs_direct_greedy():
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(4)]
    truth = [_greedy(cfg, params, p, 5) for p in prompts]
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST)
    for p in prompts:
        eng.submit(p, 5)
    m = eng.run(300)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))
    assert m.preemptions > 0
    assert eng.kv.meter.bytes_host > 0


def test_paged_engine_under_local_page_pressure():
    """LOCAL pool sized for ~1 request: the scheduler must plan in pages,
    serving requests in fair rotation without corrupting any KV."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(3)]
    truth = [_greedy(cfg, params, p, 5) for p in prompts]
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=1)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST,
                        kv=kv)
    assert (eng.sched.page_budget == kv.page_budget).all()
    for p in prompts:
        eng.submit(p, 5)
    eng.run(400)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))


def test_ttft_includes_full_step_time_on_both_paths():
    """Regression: the prefill path recorded TTFT without the current step's
    accrued time while the decode path included it — they now agree: TTFT of
    an arrival-0 request whose first token lands in step 0 is exactly the
    simulated duration of step 0."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="cfs", slice_tokens=3, offload_tier=HOST)
    r = eng.submit([1, 2, 3, 4], 4, arrival=0.0)
    eng.step()
    m = eng.metrics
    assert r.generated, "prefill must emit the first token"
    assert m.ttft[r.rid] == pytest.approx(m.sim_time)
    assert m.ttft[r.rid] > 0.0


def test_park_meters_exactly_resident_tokens():
    """Regression: parking used to compute the tail fill from the nominal
    context length, so a request whose resident KV ended exactly on a page
    boundary metered a FULL page at 1/page fill. Park meters precisely
    n_tokens of native-dtype KV, for any alignment."""
    cfg = smoke_config(get_config(ARCH))
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, max_running=1)
    kv.add_remote_lease("d0", 64 * kv.aqua.page_bytes)
    for resident in (3, 8, 9, 16):            # sub-page, boundary, +1, 2 pages
        rid = resident
        kv.ensure_capacity(rid, resident + 1)  # engine ensures ctx, parks ctx-1
        before = kv.meter.bytes_fabric
        kv.park(rid, resident, prefer=REMOTE)
        moved = kv.meter.bytes_fabric - before
        assert moved == pytest.approx(kv.footprint_bytes(resident)), resident
        kv.restore(rid)
        kv.release(rid)


def test_fcfs_paged_budgets_to_completion_under_pressure():
    """Regression: FCFS admission budgeted only one slice of growth, so
    admitted requests outgrew the LOCAL pool mid-serve and the engine died
    with MemoryError. FCFS never preempts, so it must admit only what fits
    to completion — later arrivals wait (the paper's Fig. 1a starvation),
    but every request completes correctly."""
    cfg = smoke_config(get_config(ARCH))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(2)]
    truth = [_greedy(cfg, params, p, 20) for p in prompts]
    # pages to completion: ceil(28/8)=4 pages x 4 layers = 16 per request;
    # a 20-page budget forces strictly serial FCFS admission
    kv = PagedStateRuntime(cfg, max_seq=64, page_tokens=8, local_pages=21)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=64,
                        scheduler="fcfs", offload_tier=HOST, kv=kv)
    for p in prompts:
        eng.submit(p, 20)
    eng.run(600)
    got = {tuple(r.prompt_tokens): r.generated for r in eng.finished}
    assert all(got[tuple(p)] == t for p, t in zip(prompts, truth))


# ---------------------------------------------------------------------------
# microbenchmark invariants (the acceptance numbers)
# ---------------------------------------------------------------------------
def test_context_switch_benchmark_coalescing_invariants():
    from benchmarks.context_switch import measure
    m = measure(arch=ARCH, ctx_len=52, page_tokens=8, max_seq=64)
    # paged preempt moves ONLY native-dtype payload (tail at its fill)...
    assert m["paged/preempt_bytes"] <= m["native_state_bytes"] + 1e-6
    # ...as one coalesced message per (plane, tier, donor) group
    assert m["paged/preempt_messages"] == 1
    assert m["paged/roundtrip_messages"] == 2
    # the seed blob path paid the f32 repack: ~2x for a bf16 model
    assert m["blob/preempt_bytes"] >= 1.9 * m["native_state_bytes"]
