"""AQUA core behaviour tests: tiered tensors, coordinator protocol, placer
optimality, control loops, and the paper's headline claims in the simulator.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.aqua_tensor import HOST, LOCAL, REMOTE, AquaTensor, TransferMeter
from repro.core.control_loop import BatchInformer, LLMInformer
from repro.core.coordinator import Coordinator
from repro.core.perfmodel import A100_NVLINK, TPU_V5E, ModelCost
from repro.core.placer import ModelSpec, place
from repro.core.simulator import (Request, ServingSimulator,
                                  long_prompt_tokens_per_s)


# ---------------------------------------------------------------------------
# AquaTensor
# ---------------------------------------------------------------------------
def _mk_tensor(**kw):
    args = dict(n_logical=32, page_shape=(4, 8), local_slots=8, host_slots=32,
                dtype=jnp.float32)
    args.update(kw)
    return AquaTensor(**args)


def test_aqua_tensor_offload_fetch_roundtrip():
    t = _mk_tensor()
    t.add_remote_lease("donor0", 16)
    lps = t.allocate(6)
    data = jnp.arange(6 * 4 * 8, dtype=jnp.float32).reshape(6, 4, 8)
    t.write_local(lps, data)
    t.offload(lps[:4], prefer=REMOTE)
    assert t.tier_counts() == {"local": 2, "remote": 4, "host": 0}
    np.testing.assert_array_equal(np.asarray(t.read(lps)), np.asarray(data))
    t.ensure_local(lps)
    assert t.tier_counts()["local"] == 6
    np.testing.assert_array_equal(np.asarray(t.read(lps)), np.asarray(data))


def test_aqua_tensor_spills_to_host_when_no_lease():
    t = _mk_tensor(local_slots=4)
    lps = t.allocate(4)
    data = jnp.ones((4, 4, 8), jnp.float32)
    t.write_local(lps, data)
    t.offload(lps, prefer=REMOTE)             # no donor -> host fallback
    assert t.tier_counts()["host"] == 4
    np.testing.assert_array_equal(np.asarray(t.read(lps)), np.asarray(data))


def test_aqua_tensor_elastic_reclaim_preserves_data():
    t = _mk_tensor()
    t.add_remote_lease("donor0", 8)
    lps = t.allocate(8)
    data = jnp.asarray(np.random.default_rng(0).standard_normal((8, 4, 8)),
                       jnp.float32)
    t.write_local(lps, data)
    t.offload(lps, prefer=REMOTE)
    moved = t.evict_remote("donor0")          # donor reclaims its HBM
    assert moved == 8
    assert t.tier_counts() == {"local": 0, "remote": 0, "host": 8}
    np.testing.assert_array_equal(np.asarray(t.read(lps)), np.asarray(data))


def _offload_time(page_shape, tier):
    meter = TransferMeter(hw=A100_NVLINK)
    t = _mk_tensor(meter=meter, local_slots=16, page_shape=page_shape,
                   host_slots=16)
    t.add_remote_lease("d", 16)
    lps = t.allocate(16)
    t.write_local(lps, jnp.ones((16,) + page_shape, jnp.float32))
    t.offload(lps, prefer=tier)
    return meter.sim_time


def test_meter_reproduces_fig3a_coalescing_economics():
    """Small transfers don't benefit from the fabric (paper Fig. 3a: NVLink is
    latency-bound below ~MB); large coalesced transfers win by ~bandwidth
    ratio. This asymmetry is the reason AQUA TENSORS coalesce."""
    small_f = _offload_time((4, 8), REMOTE)           # 2 KB total
    small_h = _offload_time((4, 8), HOST)
    assert small_f > 0.5 * small_h                    # no meaningful win
    big_f = _offload_time((256, 1024), REMOTE)        # 16 MB total
    big_h = _offload_time((256, 1024), HOST)
    assert big_f < big_h / 4.0                        # fabric wins big


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(0, 2), st.data())
def test_aqua_tensor_property_read_invariant(n, moves, data):
    """Property: page payloads survive any sequence of tier migrations."""
    t = _mk_tensor(local_slots=16, host_slots=32)
    t.add_remote_lease("d0", 8)
    lps = t.allocate(n)
    rng = np.random.default_rng(n * 7 + moves)
    payload = jnp.asarray(rng.standard_normal((n, 4, 8)), jnp.float32)
    t.write_local(lps, payload)
    for _ in range(moves):
        sel = lps[: data.draw(st.integers(1, n))]
        tier = data.draw(st.sampled_from([REMOTE, HOST]))
        t.offload(sel, prefer=tier)
        t.ensure_local(sel)
    t.ensure_local(lps)
    np.testing.assert_array_equal(np.asarray(t.read(lps)), np.asarray(payload))


# ---------------------------------------------------------------------------
# Coordinator protocol
# ---------------------------------------------------------------------------
def test_coordinator_lease_allocate_reclaim_cycle():
    c = Coordinator(strict_pairing=False)
    c.offer("gpu0", 30e9)
    grants = c.allocate("gpu1", 10e9)
    assert grants == [("gpu0", 10e9)]
    c.request_reclaim("gpu0")
    assert c.pending_reclaims("gpu1") == ["gpu0"]
    assert not c.reclaim_status("gpu0")       # consumer hasn't released yet
    c.free("gpu1", "gpu0", 10e9)
    assert c.reclaim_status("gpu0")


def test_coordinator_strict_pairing_routes_to_matched_producer():
    c = Coordinator(strict_pairing=True)
    c.set_pairing({"llm0": "sd0"})
    c.offer("sd0", 20e9)
    c.offer("sd1", 40e9)                      # bigger, but not the match
    assert c.allocate("llm0", 5e9) == [("sd0", 5e9)]


def test_coordinator_falls_back_to_empty_when_no_producers():
    c = Coordinator()
    assert c.allocate("llm0", 5e9) == []      # engine then uses host DRAM


# ---------------------------------------------------------------------------
# Placer
# ---------------------------------------------------------------------------
def test_placer_matches_paper_fig4():
    models = [ModelSpec("sd-0", 30, "producer"), ModelSpec("sd-1", 30, "producer"),
              ModelSpec("llm-0", -25, "consumer"), ModelSpec("llm-1", -25, "consumer")]
    p = place(models, 2, 2, 80.0, solver="bnb")
    for s, ms in p.servers().items():
        kinds = sorted(m.split("-")[0] for m in ms)
        assert kinds == ["llm", "sd"]
    assert len(p.pairs) == 2


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 4), st.integers(2, 3), st.data())
def test_placer_bnb_is_optimal_vs_bruteforce(S, G, data):
    import itertools
    M = data.draw(st.integers(2, min(6, S * G)))
    models = []
    for i in range(M):
        kind = data.draw(st.sampled_from(["producer", "consumer"]))
        mem = data.draw(st.sampled_from([10.0, 25.0, 40.0]))
        models.append(ModelSpec(f"m{i}", mem if kind == "producer" else -mem, kind))
    p = place(models, S, G, 80.0, solver="bnb")
    # brute force
    from repro.core.placer import _objective
    best = min(
        (_objective(models, a, S, 80.0)
         for a in itertools.product(range(S), repeat=M)
         if max(np.bincount(a, minlength=S)) <= G),
    )
    assert p.objective <= best + 1e-9


def test_placer_scales_to_128_gpus_quickly():
    # paper appendix A.1: 128 GPUs, mixed modalities, < 45 s
    models = []
    for i in range(42):
        models.append(ModelSpec(f"img{i}", 30.0, "producer"))
        models.append(ModelSpec(f"aud{i}", 40.0, "producer"))
        models.append(ModelSpec(f"llm{i}", -35.0, "consumer"))
    p = place(models, 16, 8, 80.0, solver="greedy")
    assert p.solve_time < 45.0
    assert len(p.assignment) == 126


# ---------------------------------------------------------------------------
# Control loops
# ---------------------------------------------------------------------------
def test_llm_informer_donates_then_reclaims():
    c = Coordinator(strict_pairing=False)
    inf = LLMInformer("llm0", c, total_bytes=40e9, reserve_bytes=5e9,
                      low_rate=2.0, high_rate=4.0, window=2)
    d = inf.inform_stats(pending_requests=1, kv_utilization=0.1)
    assert d.donate and d.delta_bytes == -(35e9)
    assert c.allocate("peer", 1e9) == [("llm0", 1e9)]
    # traffic spike -> reclaim requested; completes once peer frees
    d = inf.inform_stats(pending_requests=50, kv_utilization=0.9)
    assert d.reclaim and d.delta_bytes == 0.0
    c.free("peer", "llm0", 1e9)
    d = inf.inform_stats(pending_requests=50, kv_utilization=0.9)
    assert d.reclaim and d.delta_bytes == 35e9


def test_batch_informer_donates_non_working_set():
    c = Coordinator(strict_pairing=False)
    inf = BatchInformer("sd0", c, total_bytes=80e9, working_set_bytes=50e9)
    d = inf.inform_stats()
    assert d.donate and d.delta_bytes == -30e9


# ---------------------------------------------------------------------------
# Paper headline claims (simulator, A100 profile)
# ---------------------------------------------------------------------------
def _codellama_sim(scheduler, tier, reqs):
    cfg = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2
    sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                           kv_capacity_bytes=80e9 - wb - 2e9,
                           scheduler=scheduler, offload_tier=tier,
                           max_running=20)
    return sim.run(reqs)


def _mkreqs(rate, n=80, seed=0):
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(i, float(arr[i]), int(rng.integers(400, 1600)),
                    int(rng.integers(150, 500))) for i in range(n)]


def test_cfs_improves_ttft_multiple_x():
    """Paper Fig. 9: CFS cuts TTFT multiple-x under bursty load (the paper's
    4x shows up in the queued tail: vLLM starves late arrivals)."""
    r_v = _codellama_sim("vllm", "host", _mkreqs(5.0))
    r_a = _codellama_sim("cfs", "fabric", _mkreqs(5.0))
    def p90(xs):
        xs = sorted(xs)
        return xs[int(0.9 * len(xs))]
    assert p90(r_a.ttfts()) < p90(r_v.ttfts()) / 2.0
    assert r_a.p50(r_a.ttfts()) < r_v.p50(r_v.ttfts()) / 1.8


def test_aqua_recovers_cfs_rct_penalty():
    """Paper Fig. 1b/9: CFS over PCIe inflates RCT; AQUA recovers most of it."""
    r_h = _codellama_sim("cfs", "host", _mkreqs(5.0, seed=1))
    r_f = _codellama_sim("cfs", "fabric", _mkreqs(5.0, seed=1))
    assert r_f.p50(r_f.rcts()) < r_h.p50(r_h.rcts())


def test_long_prompt_6x_on_paper_hardware():
    """Paper Fig. 7: ~6x tokens in the same wall time vs FlexGen."""
    cfg = get_config("aqua-opt-30b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2
    free = 80e9 - wb - 12e9
    th_h = long_prompt_tokens_per_s(A100_NVLINK, mc, ctx_tokens=8000,
                                    free_hbm_bytes=free, weight_bytes=wb, tier="host")
    th_f = long_prompt_tokens_per_s(A100_NVLINK, mc, ctx_tokens=8000,
                                    free_hbm_bytes=free, weight_bytes=wb, tier="fabric")
    assert 4.0 < th_f / th_h < 8.0            # paper: 6x


def test_fabric_bandwidth_curve_matches_fig3a():
    # ~100 GB/s at 2 MB, >= 230 GB/s for large buffers, tiny for small ones
    bw2mb = A100_NVLINK.fabric.effective_bw(2e6)
    assert 80e9 < bw2mb < 120e9
    assert A100_NVLINK.fabric.effective_bw(1e9) > 230e9
    assert A100_NVLINK.fabric.effective_bw(64e3) < 10e9
