"""Regenerate the auto-generated tables section of EXPERIMENTS.md from the
dry-run results (optimized) and the preserved baseline artifacts.

    PYTHONPATH=src python scripts/update_experiments.py
"""
import re
import sys

sys.path.insert(0, "src")

from repro.analysis.report import summarize  # noqa: E402

BEGIN = "<!-- BEGIN GENERATED TABLES -->"
END = "<!-- END GENERATED TABLES -->"


def main():
    parts = ["", "## Optimized (current defaults)", "",
             summarize("results/dryrun")]
    try:
        parts += ["", "## Paper-faithful baseline (pre-hillclimb, preserved)",
                  "", summarize("results/dryrun_baseline")]
    except Exception as e:
        parts += ["", f"(baseline tables unavailable: {e})"]
    body = "\n".join(parts)

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = re.sub(re.escape(BEGIN) + ".*" + re.escape(END),
                  BEGIN + "\n" + body + "\n" + END, text, flags=re.S)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
