#!/usr/bin/env python
"""Perf-regression gate over the committed BENCH_*.json trajectories.

Compares the working tree's BENCH files against the same files at a base
commit (default ``HEAD~1``, i.e. the previous PR tip on a linear history)
and FAILS when a gated metric regressed by more than the threshold:

  * step-time tail latency   — leaf keys containing ``step_time_p99``
  * kernel-launch pressure   — leaf keys containing ``launches_per_step``
  * burst tail latency       — leaf keys containing ``ttft_p99``
    (``admission_off`` segments exempt: the baseline diverging is the
    benchmark's POINT, not a regression)
  * crash-restart cost       — leaf keys containing ``recovery_time``
  * cancel teardown cost     — leaf keys containing ``reclaim_latency``

Only INCREASES fail (these metrics are all lower-is-better), only beyond
``--threshold`` (default 15%) relative, and only above a small absolute
floor so sub-microsecond jitter near zero can't trip the gate. Paths
holding the per-request BASELINE trajectories (``per_request`` /
``baseline`` segments) are exempt: the baseline growing while the fused
numbers hold is the fused path getting MORE work for the same launches,
not a regression. Wall-clock keys (``wall_`` prefix) are never gated —
shared-CI wall time is not a perf surface.

A file or base commit that does not exist yet passes with a note (first
PR that introduces a trajectory has nothing to diff against).

    python scripts/check_bench_regression.py [--base HEAD~1] [--threshold 0.15]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

GATED_SUBSTRINGS = ("step_time_p99", "launches_per_step", "ttft_p99",
                    "recovery_time", "reclaim_latency")
EXEMPT_SEGMENTS = ("per_request", "baseline", "no_speculation",
                   "admission_off")
ABS_FLOOR = 1e-9          # seconds / launches below this never gate


def flatten(obj, prefix=""):
    out = {}
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            out.update(flatten(v, f"{prefix}{k}/"))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix.rstrip("/")] = float(obj)
    return out


def gated(path: str) -> bool:
    leaf = path.rsplit("/", 1)[-1]
    if leaf.startswith("wall_"):
        return False
    if any(seg in path for seg in EXEMPT_SEGMENTS):
        return False
    return any(s in leaf for s in GATED_SUBSTRINGS)


def base_blob(base: str, name: str, repo: str):
    try:
        out = subprocess.run(
            ["git", "show", f"{base}:{name}"], cwd=repo,
            capture_output=True, text=True, check=True).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="HEAD~1",
                    help="git rev holding the reference BENCH files")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max allowed relative increase on gated metrics")
    ap.add_argument("files", nargs="*",
                    help="BENCH files to check (default: BENCH_*.json)")
    args = ap.parse_args()

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = args.files or sorted(
        os.path.relpath(p, repo)
        for p in glob.glob(os.path.join(repo, "BENCH_*.json")))
    failures = []
    checked = 0
    for name in files:
        with open(os.path.join(repo, name)) as f:
            head = flatten(json.load(f))
        base = base_blob(args.base, name, repo)
        if base is None:
            print(f"  {name}: no base at {args.base} (new trajectory) -- ok")
            continue
        base = flatten(base)
        for path, new in sorted(head.items()):
            if not gated(path) or path not in base:
                continue
            old = base[path]
            checked += 1
            if old <= ABS_FLOOR or new <= old:
                continue
            rel = (new - old) / old
            status = "FAIL" if rel > args.threshold else "ok"
            if rel > args.threshold:
                failures.append((name, path, old, new, rel))
            if rel > 0.02 or status == "FAIL":
                print(f"  {name}:{path}: {old:.6g} -> {new:.6g} "
                      f"(+{100 * rel:.1f}%) {status}")
    print(f"bench gate: {checked} gated metrics vs {args.base}, "
          f"{len(failures)} over the {100 * args.threshold:.0f}% threshold")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
