"""Docs-health check (wired into CI and tier-1 via tests/test_docs_health.py).

Two invariants over README.md and docs/*.md:

  1. every fenced ```python code block compiles, and its import statements
     execute cleanly against src/ (so examples in the docs can't reference
     modules/symbols that drifted away);
  2. every intra-repo markdown link ([text](path) that is not http/mailto/
     anchor) resolves to an existing file relative to the document.

    PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(ROOT))
    except ValueError:
        return str(path)

_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.S)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def python_blocks(text: str):
    return _BLOCK_RE.findall(text)


def check_block(block: str, where: str):
    """Compile the whole block; execute only its import statements (found
    via the AST, so multi-line/parenthesized/indented imports work)."""
    try:
        tree = ast.parse(block, where)
    except SyntaxError as e:
        return [f"{where}: syntax error in python block: {e}"]
    imports = [node for node in ast.walk(tree)
               if isinstance(node, (ast.Import, ast.ImportFrom))
               and getattr(node, "level", 0) == 0]
    if not imports:
        return []
    mod = ast.fix_missing_locations(ast.Module(body=imports,
                                               type_ignores=[]))
    try:
        exec(compile(mod, where, "exec"), {})
    except Exception as e:
        return [f"{where}: import failed: {e!r}"]
    return []


def check_links(path: pathlib.Path, text: str):
    errors = []
    for m in _LINK_RE.finditer(text):
        url = m.group(1)
        if url.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = (path.parent / url.split("#", 1)[0]).resolve()
        if not target.exists():
            errors.append(f"{_rel(path)}: broken link -> {url}")
    return errors


def check_file(path: pathlib.Path):
    text = path.read_text()
    errors = check_links(path, text)
    for i, block in enumerate(python_blocks(text)):
        errors += check_block(block,
                              f"{_rel(path)}[python block {i}]")
    return errors


def main() -> int:
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    missing = [f for f in files if not f.exists()]
    errors = [f"missing doc: {f}" for f in missing]
    checked = 0
    for f in files:
        if f.exists():
            errors += check_file(f)
            checked += 1
    for e in errors:
        print(f"ERROR: {e}")
    print(f"docs-health: {checked} files checked, {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
