"""Multi-tenant serving: an LLM consumer co-located with a compute-bound
producer, wired through the AQUA coordinator — the paper's end-to-end flow
(placement -> lease -> CFS serving -> traffic spike -> elastic reclaim).

    PYTHONPATH=src python examples/serve_cfs.py
"""
import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import REMOTE
from repro.core.control_loop import BatchInformer
from repro.core.coordinator import Coordinator
from repro.core.placer import ModelSpec, place
from repro.models import api
from repro.serving.engine import ServingEngine


def main():
    # 1. AQUA-PLACER: co-locate the memory-bound LLM with the producer
    models = [ModelSpec("llm-qwen", -25.0, "consumer"),
              ModelSpec("img-sd", 30.0, "producer"),
              ModelSpec("llm-mistral", -20.0, "consumer"),
              ModelSpec("aud-gen", 25.0, "producer")]
    placement = place(models, n_servers=2, gpus_per_server=2, gpu_mem=80.0,
                      solver="bnb")
    print("placement:", placement.servers())
    print("pairs:", placement.pairs)

    # 2. coordinator + producer informer offers the spare HBM
    coord = Coordinator(strict_pairing=True)
    coord.set_pairing(dict(placement.pairs))
    BatchInformer("img-sd", coord, total_bytes=80e9,
                  working_set_bytes=50e9).inform_stats()
    print("offers:", coord.stats())

    # 3. consumer engine leases it and serves with CFS; the page-native
    #    runtime puts the leased HBM directly behind the decode KV pages
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3,
                        offload_tier=REMOTE, coordinator=coord,
                        name="llm-qwen", want_remote_bytes=1e9,
                        respond_every=2)
    print("runtime: unified paged state; planes:", list(eng.kv.planes))
    rng = np.random.default_rng(2)
    for i in range(6):
        eng.submit(list(map(int, rng.integers(0, cfg.vocab_size, 10))), 8)
    for _ in range(25):
        eng.step()

    # 4. producer load spikes -> reclaim; engine evacuates at the boundary
    coord.request_reclaim("img-sd")
    eng.run(500)
    print(f"served {len(eng.finished)}/6; reclaim complete: "
          f"{coord.reclaim_status('img-sd')}")
    print("KV tiers after reclaim:", eng.pager.stats()["tiers"])
    assert coord.reclaim_status("img-sd")
    assert eng.pager.stats()["tiers"]["remote"] == 0
    print("serve_cfs OK")


if __name__ == "__main__":
    main()
