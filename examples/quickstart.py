"""Quickstart: train a tiny model, then serve it with CFS + AQUA paging and
copy-on-write prompt-prefix sharing.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.aqua_tensor import REMOTE
from repro.serving.engine import ServingEngine
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig, cosine_schedule
from repro.training.train_loop import TrainConfig, train


def main():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    print(f"arch: {cfg.name} (smoke: {cfg.n_layers}L d={cfg.d_model})")

    # 1. train for a few steps
    out = train(cfg, DataConfig(seed=0, batch=8, seq_len=64),
                AdamWConfig(lr=cosine_schedule(3e-3, 5, 60)),
                TrainConfig(steps=60), seed=0)
    print(f"train: loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    assert out["losses"][-1] < out["losses"][0]

    # 2. serve it: CFS time-slices + AQUA page-table tier flips
    eng = ServingEngine(cfg, out["params"], max_running=2, max_seq=96,
                        scheduler="cfs", slice_tokens=3,
                        offload_tier=REMOTE)
    eng.pager.add_remote_lease("donor-gpu", 1 << 22)      # a neighbor's HBM
    rng = np.random.default_rng(1)
    # a shared 16-token "system prompt" + per-user tails: once the first
    # request prefills it, later arrivals adopt its physical pages
    system = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    lead = eng.submit(system + [1, 2], 6)
    while not lead.prefilled:
        eng.step()
    for i in range(5):
        eng.submit(system
                   + list(map(int, rng.integers(0, cfg.vocab_size, 4))), 6)
    m = eng.run(500)
    sh = eng.kv.stats()["sharing"]
    print(f"serve: {len(eng.finished)} requests, "
          f"{m.preemptions} preemptions paged over the fabric, "
          f"{eng.pager.stats()['meter']['bytes_fabric']/1e6:.2f} MB moved")
    print(f"prefix sharing: {sh['prefix_hits']} hits, "
          f"{sh['adopted_tokens']} prompt tokens adopted, "
          f"{sh['cow_copies']} copy-on-write clones")
    assert sh["prefix_hits"] == 5
    # 3. every request above has finished — yet a NEW arrival with the same
    # system prompt still skips its prefill: the radix prefix cache retained
    # the refcount-0 pages (evicted only under real page pressure)
    late = eng.submit(system
                      + list(map(int, rng.integers(0, cfg.vocab_size, 4))), 6)
    eng.run(500)
    cache = eng.kv.stats()["cache"]
    print(f"prefix cache: {cache['hits']} hit(s) after drain, "
          f"{cache['hit_tokens']} prefill tokens revived for request "
          f"{late.rid}")
    assert late.done and cache["hits"] >= 1
    print("quickstart OK")


if __name__ == "__main__":
    main()
