"""Long-prompt inference throughput: FlexGen-style host offload vs AQUA
fabric offload (paper Fig. 7), on the paper's A100 testbed constants and on
the TPU v5e port.

    PYTHONPATH=src python examples/long_prompt.py
"""
from repro.configs import get_config
from repro.core.perfmodel import A100_NVLINK, TPU_V5E, ModelCost
from repro.core.simulator import long_prompt_tokens_per_s


def main():
    cfg = get_config("aqua-opt-30b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2
    print(f"model: OPT-30B ({wb/1e9:.0f} GB bf16); prompt 8000 tokens "
          f"-> KV {mc.kv_bytes(8000)/1e9:.1f} GB")
    for hw in (A100_NVLINK, TPU_V5E):
        free = max(hw.hbm_bytes - wb - 12e9, 2e9)
        host = long_prompt_tokens_per_s(hw, mc, ctx_tokens=8000,
                                        free_hbm_bytes=free,
                                        weight_bytes=min(wb, hw.hbm_bytes * 0.8),
                                        tier="host")
        fab = long_prompt_tokens_per_s(hw, mc, ctx_tokens=8000,
                                       free_hbm_bytes=free,
                                       weight_bytes=min(wb, hw.hbm_bytes * 0.8),
                                       tier="fabric")
        print(f"{hw.name:12s}: host {host:6.2f} tok/s | fabric {fab:6.2f} "
              f"tok/s | {fab/host:.1f}x  (paper: 6x on A100/NVLink)")


if __name__ == "__main__":
    main()
