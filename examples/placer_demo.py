"""AQUA-PLACER demo: place a mixed-modality fleet on a cluster and verify
every consumer gets a producer on its scale-up domain (paper §4, Fig. 4).

    PYTHONPATH=src python examples/placer_demo.py
"""
from repro.core.placer import ModelSpec, place


def main():
    fleet = []
    for i in range(4):
        fleet.append(ModelSpec(f"sd-{i}", 30.0, "producer"))
        fleet.append(ModelSpec(f"audiogen-{i}", 40.0, "producer"))
        fleet.append(ModelSpec(f"codellama-{i}", -45.0, "consumer"))
        fleet.append(ModelSpec(f"mistral-{i}", -20.0, "consumer"))
    p = place(fleet, n_servers=8, gpus_per_server=2, gpu_mem=80.0)
    print(f"solver={p.solver} objective={p.objective:.1f} "
          f"time={p.solve_time*1e3:.0f} ms")
    for s, models in sorted(p.servers().items()):
        print(f"  server {s}: {models}")
    print("consumer -> producer pairs:")
    for c, pr in p.pairs:
        print(f"  {c:15s} offloads to {pr}")
    assert len(p.pairs) == 8, "every consumer must be paired"
    print("placer_demo OK")


if __name__ == "__main__":
    main()
