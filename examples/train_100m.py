"""End-to-end driver: train a ~100M-parameter qwen-family model for a few
hundred steps with checkpoint/restart, on CPU.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig, cosine_schedule
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: qwen1.5-0.5b family, trimmed width/depth, 32k vocab
    cfg = get_config("qwen1.5-0.5b").replace(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=1408, vocab_size=32768, max_seq_len=args.seq,
        param_dtype="float32", compute_dtype="float32")
    n = cfg.param_count()
    print(f"training {cfg.name}-100m: {n/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    with tempfile.TemporaryDirectory() as ckdir:
        out = train(
            cfg,
            DataConfig(seed=0, batch=args.batch, seq_len=args.seq),
            AdamWConfig(lr=cosine_schedule(3e-4, warmup=20, total=args.steps)),
            TrainConfig(steps=args.steps, ckpt_dir=ckdir, ckpt_every=100,
                        remat=True),
            seed=0,
            hooks={"on_step": lambda s, st: print(
                f"step {s:4d} loss {float(st['loss']):.4f}", flush=True)
                if s % 25 == 0 else None})
    l0 = sum(out["losses"][:10]) / 10
    l1 = sum(out["losses"][-10:]) / 10
    print(f"mean loss: first10 {l0:.4f} -> last10 {l1:.4f}")
    assert l1 < l0, "loss must decrease"
    print("train_100m OK")


if __name__ == "__main__":
    main()
