"""Request-lifecycle benchmark: cancellation reclaim latency, crash-
consistent snapshot/restore recovery time, and abandonment/deadline
shedding under load.

Two clocks, as everywhere in this repo:

  * engine     — REAL numerics (smoke model, unified paged runtime):
                 (A) a request is cancelled out of each lifecycle state
                 (waiting / prefilling / running) and the benchmark counts
                 the ADDITIONAL steps until no plane holds its pages —
                 the acceptance bar is reclamation within one step, with
                 the full-state auditor confirming zero leaks; (B) an
                 ``engine_crash`` fault kills the engine mid-stream with a
                 snapshot journaled at every step boundary, and the run
                 restarts from the last record — reporting the recovery
                 time (simulated seconds from the journal point to
                 completion) and whether the resumed streams finished
                 bit-identically.
  * simulator  — paper scale (CodeLlama-34B on A100, CFS over fabric
                 offload): the fault-recovery trace with 30 % of clients
                 abandoning (``make_cancel_events``) and with a TTFT SLO
                 as a hard deadline — what reclaiming torn-down work buys
                 the survivors.

Writes ``BENCH_lifecycle.json`` next to the repo root; the
``recovery_time`` / ``reclaim_latency`` keys feed the perf gate
(``scripts/check_bench_regression.py``).

    PYTHONPATH=src python -m benchmarks.lifecycle
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import codellama_sim, make_requests, pct as _pct

N_REQ = 48
RATE = 40.0
CANCEL_FRAC = 0.3
TTFT_SLO_S = 2.0


def measure_engine(arch: str = "qwen1.5-0.5b") -> Dict[str, Dict]:
    import jax
    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import HOST
    from repro.core.errors import EngineCrashError
    from repro.core.faults import FaultEvent, FaultInjector, InvariantAuditor
    from repro.models import api
    from repro.serving.engine import ServingEngine

    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, 1 + rng.integers(0, cfg.vocab_size - 1, 12)))
               for _ in range(4)]

    def build(faults=None):
        return ServingEngine(cfg, params, max_running=2, max_seq=64,
                             scheduler="cfs", slice_tokens=4,
                             offload_tier=HOST, step_tokens=8,
                             prefetch=False, faults=faults)

    # -- A: cancel out of each lifecycle state; count the extra steps
    #    until every plane page of the victim is back on a free list
    eng = build()
    rs = [eng.submit(p, 6) for p in prompts]
    auditor = InvariantAuditor()

    def reclaim_steps(r) -> int:
        eng.cancel(r.rid)
        for extra in range(4):
            if all(r.rid not in p.pages for p in eng.kv.planes.values()):
                return extra
            eng.step()
        return 4

    lat = {"waiting": reclaim_steps(rs[3])}
    eng.step()
    pre = next(x for x in (rs[0], rs[1]) if x.lifecycle == "prefilling")
    lat["prefilling"] = reclaim_steps(pre)
    other = rs[1] if pre is rs[0] else rs[0]
    while not other.generated:
        eng.step()
    lat["running"] = reclaim_steps(other)
    leaks = auditor.check(eng.kv, engine=eng)
    eng.run(500)
    reclaim = {f"reclaim_latency_steps_{k}": float(v)
               for k, v in lat.items()}
    reclaim["reclaim_latency_steps_max"] = float(max(lat.values()))
    reclaim["invariant_violations"] = float(len(leaks))

    # -- B: crash mid-stream, journal every step boundary, restart from
    #    the last record, finish — recovery time on the simulated clock
    base = build()
    for p in prompts:
        base.submit(p, 6)
    mb = base.run(500)
    want = {tuple(r.prompt_tokens): r.generated for r in base.finished}

    fi = FaultInjector(seed=0, events=[
        FaultEvent(kind="engine_crash", at_step=4)])
    eng = build(faults=fi)
    for p in prompts:
        eng.submit(p, 6)
    snap, t_snap = eng.snapshot(), 0.0
    try:
        for _ in range(500):
            snap = eng.snapshot()
            t_snap = float(eng.metrics.sim_time)
            eng.step()
            if not (eng.waiting or eng.running):
                break
    except EngineCrashError:
        pass
    restored = ServingEngine.restore(cfg, params, snap)
    mr = restored.run(500)
    got = {tuple(r.prompt_tokens): r.generated for r in restored.finished}
    crash = {
        "recovery_time_s": float(mr.sim_time) - t_snap,
        "makespan_uninterrupted_s": float(mb.sim_time),
        "makespan_with_crash_s": float(mr.sim_time),
        "snapshot_pages": float(sum(len(ps["lps"]) for ps in
                                    snap["kv"]["planes"].values())),
        "tokens_bit_identical": float(got == want),
    }
    return {"engine_reclaim": reclaim, "engine_crash_restore": crash}


def measure_sim() -> Dict[str, Dict]:
    from repro.core.faults import FaultInjector
    from repro.core.perfmodel import A100_NVLINK
    from repro.core.workload import make_cancel_events

    def reqs(**kw):
        return make_requests(rate=RATE, n=N_REQ, seed=3,
                             prompt=(300, 1200), gen=(60, 200), **kw)

    def run(rs, faults=None):
        sim = codellama_sim(A100_NVLINK, "cfs", "fabric", step_tokens=256,
                            max_running=8, faults=faults)
        res = sim.run(rs)
        fin = [r for r in res.requests if r.finish is not None]
        return sim, {
            "finished_requests": float(len(fin)),
            "cancelled_requests": float(sim.cancelled),
            "deadline_missed": float(sim.deadline_missed),
            "ttft_p99_s": _pct([r.ttft - r.arrival for r in fin
                                if r.ttft is not None], 0.99),
            "rct_p99_s": _pct([r.finish - r.arrival for r in fin], 0.99),
            "makespan_s": float(max(r.finish for r in fin)),
        }

    _, free = run(reqs())
    fi = FaultInjector(seed=7, events=make_cancel_events(
        reqs(), frac=CANCEL_FRAC, seed=7, mean_wait_s=2.0))
    sim_ab, ab = run(reqs(), faults=fi)
    assert ab["cancelled_requests"] > 0
    # every survivor completes — abandoned work is reclaimed, not leaked
    assert ab["finished_requests"] + sim_ab.cancelled == N_REQ

    slo = reqs()
    for r in slo:
        r.ttft_deadline_s = TTFT_SLO_S
    _, slo_out = run(slo)
    slo_out["goodput_frac"] = slo_out["finished_requests"] / N_REQ
    return {"sim_fault_free": free,
            f"sim_abandonment_{int(CANCEL_FRAC * 100)}pct": ab,
            "sim_ttft_slo": slo_out}


def measure() -> Dict:
    out: Dict[str, Dict] = {}
    out.update(measure_engine())
    out.update(measure_sim())
    ab = out[f"sim_abandonment_{int(CANCEL_FRAC * 100)}pct"]
    out["derived"] = {
        "reclaim_within_one_step":
            out["engine_reclaim"]["reclaim_latency_steps_max"] <= 1.0,
        "crash_restore_bit_identical":
            out["engine_crash_restore"]["tokens_bit_identical"] == 1.0,
        "crash_makespan_overhead_x":
            out["engine_crash_restore"]["makespan_with_crash_s"]
            / out["engine_crash_restore"]["makespan_uninterrupted_s"],
        "abandonment_rct_p99_vs_fault_free_x":
            ab["rct_p99_s"] / out["sim_fault_free"]["rct_p99_s"],
    }
    return out


def run(m: Dict | None = None):
    m = m or measure()
    rows = []
    for scenario, vals in m.items():
        if scenario == "derived" or not isinstance(vals, dict):
            continue
        for k, v in vals.items():
            rows.append((f"lifecycle/{scenario}/{k}", float(v), ""))
    for k, v in m["derived"].items():
        rows.append((f"lifecycle/{k}", float(v),
                     "reclaimed vs fault-free"))
    return rows


def main():
    m = measure()
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_lifecycle.json")
    with open(out, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(out)}")
    print("name,value,derived")
    for name, val, derived in run(m):
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
