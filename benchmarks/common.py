"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.perfmodel import A100_NVLINK, TPU_V5E, ModelCost
from repro.core.simulator import Request, ServingSimulator


def make_requests(rate: float, n: int, seed: int = 0,
                  prompt=(400, 1600), gen=(150, 500), lora_bytes=0.0):
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(i, float(arr[i]),
                    int(rng.integers(*prompt)), int(rng.integers(*gen)),
                    lora_bytes=lora_bytes)
            for i in range(n)]


# moved to repro.core.workload (the bursty-workload module) in PR 9;
# re-exported here so existing callers keep working — import it from
# repro.core.workload in new code
from repro.core.workload import make_multi_tenant_requests  # noqa: E402,F401


def codellama_sim(hw, scheduler, tier, **kw):
    cfg = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2
    # a 34B model needs a TP group on 16GB v5e chips; A100-80G serves it solo
    while hw.hbm_bytes < wb + 10e9:
        hw = hw.pod_slice(2)
    args = dict(weight_bytes=wb, kv_capacity_bytes=hw.hbm_bytes - wb - 2e9,
                scheduler=scheduler, offload_tier=tier, max_running=20)
    args.update(kw)
    return ServingSimulator(hw, mc, **args)


def pct(xs, q):
    """Quantile of xs by sorted-index clamp (shared by every benchmark)."""
    xs = sorted(xs)
    return float(xs[min(int(q * len(xs)), len(xs) - 1)]) if xs else float("nan")
