"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.perfmodel import A100_NVLINK, TPU_V5E, ModelCost
from repro.core.simulator import Request, ServingSimulator


def make_requests(rate: float, n: int, seed: int = 0,
                  prompt=(400, 1600), gen=(150, 500), lora_bytes=0.0):
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(i, float(arr[i]),
                    int(rng.integers(*prompt)), int(rng.integers(*gen)),
                    lora_bytes=lora_bytes)
            for i in range(n)]


def make_multi_tenant_requests(n: int, n_tenants: int = 6, seed: int = 0,
                               system_prompt=(1024, 3072), tail_mean: float = 96.0,
                               gen=(40, 120), burst: float = 1.0,
                               think_time: float = 30.0):
    """Heavy-tailed multi-tenant stream for the prefix-cache benchmarks.

    Each tenant owns a system prompt (its ``prefix_group``) whose length is
    log-uniform in ``system_prompt``; per-request tails are lognormal
    (median ``tail_mean``, heavy right tail) and arrivals come in tenant
    bursts separated by exponential think time, so later members of a
    burst typically land AFTER the leader finished — the load where a
    refcount-0 cache wins and pure live sharing does not. Tenant traffic
    shares follow a Zipf-like 1/rank law (a few hot tenants, a long cold
    tail)."""
    rng = np.random.default_rng(seed)
    lo, hi = system_prompt
    sys_len = [int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
               for _ in range(n_tenants)]
    share = np.array([1.0 / (1 + t) for t in range(n_tenants)])
    share /= share.sum()
    reqs, t, i = [], 0.0, 0
    while i < n:
        tenant = int(rng.choice(n_tenants, p=share))
        t += rng.exponential(think_time)
        k = min(1 + rng.poisson(burst), n - i)
        at = t
        for _ in range(k):
            tail = int(rng.lognormal(np.log(tail_mean), 0.8)) + 1
            reqs.append(Request(
                i, float(at), sys_len[tenant] + tail,
                int(rng.integers(*gen)), prefix_group=tenant,
                shared_prefix_len=sys_len[tenant]))
            at += rng.exponential(1.0)
            i += 1
    reqs.sort(key=lambda r: r.arrival)
    for j, r in enumerate(reqs):     # rid order == arrival order
        r.rid = j
    return reqs


def codellama_sim(hw, scheduler, tier, **kw):
    cfg = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2
    # a 34B model needs a TP group on 16GB v5e chips; A100-80G serves it solo
    while hw.hbm_bytes < wb + 10e9:
        hw = hw.pod_slice(2)
    args = dict(weight_bytes=wb, kv_capacity_bytes=hw.hbm_bytes - wb - 2e9,
                scheduler=scheduler, offload_tier=tier, max_running=20)
    args.update(kw)
    return ServingSimulator(hw, mc, **args)


def pct(xs, q):
    """Quantile of xs by sorted-index clamp (shared by every benchmark)."""
    xs = sorted(xs)
    return float(xs[min(int(q * len(xs)), len(xs) - 1)]) if xs else float("nan")
