"""Burst stability: p99 TTFT under a 10x arrival spike, admission on/off.

The paper's headline claim is responsiveness under bursty request
patterns — baselines go unresponsive during arrival spikes. This
benchmark reproduces the mechanism on the 34B/A100 analytic clock:

Workload (seedable, from ``repro.core.workload``): a background stream
of long-generation "agentic" requests (median ~8k output tokens — the
KV-growth engine) plus an interactive stream of long-prompt short-output
requests (RAG-style, median ~2k prompt / 64 output) whose arrival rate
spikes 10x for 16 s via a :class:`BurstSpec` window.

Admission OFF (vLLM-style FCFS gated on *current* KV bytes): the
background set's committed terminal footprint exceeds capacity several
times over, so decode growth keeps pushing the resident set past kv_cap.
Each overshoot recompute-preempts the latest-arrived resident — exactly
the spike cohort, mid-prefill — which restarts its prefill from zero.
Under sustained growth this livelocks: spike requests are evicted before
their first token over and over (Ao et al.'s service-induced congestion)
and their TTFT diverges toward the veterans' drain time.

Admission ON (``serving/admission.py``): candidates are priced at their
TERMINAL bytes and the committed occupancy *trajectory* must peak inside
the stability region, so the background set is capped at the sustainable
level and never overshoots (zero preemptions). The short-lived spike
requests fit the temporal valley before the veterans' projected peak and
are admitted with bounded wait — p99 TTFT stays ~1-2 orders of magnitude
below the admission-off baseline.

Writes BENCH_burst.json; ``ttft_p99`` keys are gated by
scripts/check_bench_regression.py (``admission_off`` segments exempt —
the baseline is *supposed* to be terrible).
"""
from __future__ import annotations

import json
import os

from repro.core.perfmodel import A100_NVLINK
from repro.core.workload import BurstSpec, make_bursty_requests

from benchmarks.common import codellama_sim, pct

HORIZON = 1800.0
SPIKE_START, SPIKE_DURATION, SPIKE_FACTOR = 150.0, 16.0, 10.0
SEEDS = (0, 1, 2)


def build_workload(seed: int):
    """Background long-gen veterans + interactive stream with a 10x spike.

    Returns (requests, interactive_rids). rids are reassigned so the
    merged stream is rid == arrival order (the simulator's convention).
    """
    veterans = make_bursty_requests(
        24, seed=seed, base_rate=0.25,
        prompt_median=512, prompt_sigma=0.4,
        gen_median=8000, gen_sigma=0.2, max_gen=11000)
    interactive = make_bursty_requests(
        48, seed=seed + 1, base_rate=0.25,
        bursts=[BurstSpec(start=SPIKE_START, duration=SPIKE_DURATION,
                          factor=SPIKE_FACTOR)],
        prompt_median=2048, prompt_sigma=0.3,
        gen_median=64, gen_sigma=0.5)
    merged = sorted(veterans + interactive,
                    key=lambda r: (r.arrival, r.rid))
    for i, r in enumerate(merged):
        r.rid = i
    return merged, {r.rid for r in merged if r.gen_len < 400}


def censored_ttfts(requests, rids, horizon: float):
    """TTFT per request, censored at the horizon: a request never served
    its first token counts as (horizon - arrival), a LOWER bound on its
    true TTFT — divergence shows up instead of silently dropping out."""
    return [(r.ttft - r.arrival) if r.ttft is not None
            else (horizon - r.arrival)
            for r in requests if r.rid in rids]


def measure(seed: int, admission: bool) -> dict:
    requests, interactive = build_workload(seed)
    sim = codellama_sim(A100_NVLINK, "vllm", "host", step_tokens=256,
                        max_running=32, admission=admission,
                        admission_headroom=0.95, prefill_admit_limit=4)
    sim.run(requests, horizon=HORIZON)
    tt = censored_ttfts(requests, interactive, HORIZON)
    tt_all = censored_ttfts(requests, {r.rid for r in requests}, HORIZON)
    ctl = sim.admission
    return {
        "ttft_p50": pct(tt, 0.5),
        "ttft_p99": pct(tt, 0.99),
        "ttft_all_p99": pct(tt_all, 0.99),
        "unserved": sum(r.ttft is None for r in requests),
        "unfinished": sum(r.finish is None for r in requests),
        "preemptions": sim.overflow_swaps,
        "deferrals": ctl.deferred_total if ctl is not None else 0,
    }


def run() -> dict:
    out = {"config": {
        "model": "codellama-34b", "hw": "A100_NVLINK",
        "spike_factor": SPIKE_FACTOR, "spike_duration_s": SPIKE_DURATION,
        "horizon_s": HORIZON, "seeds": list(SEEDS),
    }}
    for seed in SEEDS:
        for admission in (False, True):
            key = f"seed{seed}/{'admission_on' if admission else 'admission_off'}"
            out[key] = measure(seed, admission)
    ons = [out[f"seed{s}/admission_on"]["ttft_p99"] for s in SEEDS]
    offs = [out[f"seed{s}/admission_off"]["ttft_p99"] for s in SEEDS]
    out["derived"] = {
        "worst_admission_on_ttft_p99": max(ons),
        "worst_admission_off_ttft_p99": max(offs),
        "min_off_over_on_p99_ratio": min(o / max(a, 1e-9)
                                         for o, a in zip(offs, ons)),
        # the acceptance bar: admission-off's spike-cohort p99 TTFT is
        # >5x the admission-on p99 on every seed (it diverges toward the
        # censoring horizon; admission-on stays bounded)
        "off_diverges_5x": bool(all(o > 5.0 * a
                                    for o, a in zip(offs, ons))),
    }
    return out


def main():
    res = run()
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_burst.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    d = res["derived"]
    print(f"admission off p99 TTFT (worst seed): "
          f"{d['worst_admission_off_ttft_p99']:.1f}s")
    print(f"admission on  p99 TTFT (worst seed): "
          f"{d['worst_admission_on_ttft_p99']:.1f}s")
    print(f"min off/on ratio: {d['min_off_over_on_p99_ratio']:.1f}x "
          f"(>5x on every seed: {d['off_diverges_5x']})")


if __name__ == "__main__":
    main()
