"""Mesh-tier offload benchmark: peer-HBM donor legs vs host staging.

Times the two restore paths of the paged runtime on a REAL device mesh
(on the CI box a forced 4-way host-platform mesh; on hardware the scale-up
domain itself):

  * remote  — pages parked on a donor device's slab, restored by ONE
              ``ppermute`` collective per leg (``distributed/mesh_tiers.py``)
  * host    — pages parked in host DRAM, restored over the (priced) PCIe
              host link

Reported per page-batch size:

  * the ANALYTIC clock (``TransferMeter`` pricing, what the simulator and
    every BENCH trajectory reports) — the headline remote-beats-host
    restore ratio lives here, on the paper's datasheet link constants;
  * the MEASURED wall-clock of each warm collective leg (compile call
    skipped), which feeds ``perfmodel.fit_link_model``;
  * the calibration loop closed: the relative error of the datasheet
    fabric clock vs the measured legs, against the error of the
    CALIBRATED clock (``MeshTierDomain.calibrated_profile``) on the same
    samples — calibration should collapse the error by construction.

Wall-clock keys are prefixed ``wall_`` and excluded from the CI perf gate
(host-device collectives on a shared CI box are not a perf surface); the
analytic keys are the gated trajectory.

Writes ``BENCH_mesh_offload.json`` next to the repo root.

    PYTHONPATH=src python -m benchmarks.mesh_offload
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

# the mesh needs peers: force a multi-device host platform BEFORE jax init
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import numpy as np

PAGE_SHAPE = (8, 512)                     # 16 KiB f32 pages
BATCHES = (4, 8, 16, 32)
REPEATS = 5


def _median(xs):
    return float(np.median(np.asarray(xs, np.float64)))


def _tensor(mesh):
    import jax.numpy as jnp

    from repro.core.aqua_tensor import AquaTensor, TransferMeter
    a = AquaTensor(n_logical=256, page_shape=PAGE_SHAPE, local_slots=128,
                   host_slots=128, dtype=jnp.float32, meter=TransferMeter(),
                   name="bench", mesh=mesh)
    a.add_remote_lease("donor0", 64)
    return a


def _time_leg(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def measure() -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.core.aqua_tensor import HOST, REMOTE
    from repro.core.perfmodel import TPU_V5E
    from repro.distributed.mesh_tiers import MeshTierDomain

    if not MeshTierDomain.available():
        raise SystemExit("mesh_offload needs a single-process multi-device "
                         "mesh (set --xla_force_host_platform_device_count)")
    dom = MeshTierDomain()
    a = _tensor(dom)
    rng = np.random.default_rng(0)
    hw = a.meter.hw

    out: Dict = {"page_bytes": a.page_bytes, "n_devices": dom.n_dev,
                 "batches": {}}
    for n in BATCHES:
        lps = a.allocate(n)
        data = jnp.asarray(rng.standard_normal((n,) + PAGE_SHAPE),
                           jnp.float32)
        a.write_local(lps, data)
        nbytes = n * a.page_bytes

        legs = {("remote", "park"): lambda: a.offload(lps, prefer=REMOTE),
                ("remote", "restore"): lambda: a.ensure_local(lps),
                ("host", "park"): lambda: a.offload(lps, prefer=HOST),
                ("host", "restore"): lambda: a.ensure_local(lps)}
        wall = {k: [] for k in legs}
        analytic = {}
        for i in range(REPEATS + 1):
            for key, fn in legs.items():
                t_sim0 = a.meter.sim_time
                dt = _time_leg(fn)
                if i > 0:                 # iteration 0 pays compile
                    wall[key].append(dt)
                analytic[key] = a.meter.sim_time - t_sim0
        roundtrip = np.asarray(a.read(lps))
        assert np.array_equal(roundtrip, np.asarray(data)), "corrupt restore"
        cell = {"pages": n, "message_bytes": nbytes}
        for (tier, leg), ts in wall.items():
            cell[f"analytic_{tier}_{leg}_s"] = float(analytic[(tier, leg)])
            cell[f"wall_{tier}_{leg}_s"] = _median(ts)
        cell["analytic_restore_speedup_x"] = (
            analytic[("host", "restore")] / analytic[("remote", "restore")])
        out["batches"][f"p{n:03d}"] = cell
        a.free(lps)

    # ------------------------------------------------------------------
    # calibration: the measured warm legs refit the fabric link; the
    # calibrated clock should track the measurements far better than the
    # datasheet constants do
    cal = dom.calibrated_profile(hw)
    calibrated = cal is not hw
    err_data, err_cal = [], []
    for cell in out["batches"].values():
        b = cell["message_bytes"]
        meas = _median([cell["wall_remote_park_s"],
                        cell["wall_remote_restore_s"]])
        err_data.append(abs(hw.fabric.time(b, 1) - meas) / meas)
        if calibrated:
            err_cal.append(abs(cal.fabric.time(b, 1) - meas) / meas)
    out["calibration"] = {
        "n_fabric_samples": len(dom.samples["fabric"]),
        "calibrated": bool(calibrated),
        "fabric_bw_datasheet_gbps": hw.fabric.peak_bw / 1e9,
        "fabric_bw_calibrated_gbps":
            (cal.fabric.peak_bw / 1e9) if calibrated else None,
        "fabric_latency_calibrated_us":
            (cal.fabric.latency * 1e6) if calibrated else None,
        "wall_clock_rel_error_datasheet": _median(err_data),
        "wall_clock_rel_error_calibrated":
            _median(err_cal) if err_cal else None,
    }
    big = out["batches"][f"p{max(BATCHES):03d}"]
    out["derived"] = {
        "remote_beats_host_restore":
            bool(big["analytic_remote_restore_s"]
                 < big["analytic_host_restore_s"]),
        "analytic_restore_speedup_x": big["analytic_restore_speedup_x"],
        "one_collective_per_leg":
            bool(dom.collectives == 2 * (REPEATS + 1) * len(BATCHES)),
        "calibration_tracks_measurement":
            bool(calibrated
                 and out["calibration"]["wall_clock_rel_error_calibrated"]
                 < out["calibration"]["wall_clock_rel_error_datasheet"]),
    }
    return out


def run(m: Dict | None = None):
    m = m or measure()
    rows = []
    for key, cell in m["batches"].items():
        for k, v in cell.items():
            if k.startswith("analytic"):
                rows.append((f"mesh_offload/{key}/{k}", float(v), ""))
    for k, v in m["derived"].items():
        rows.append((f"mesh_offload/{k}", float(v), "peer-HBM vs host"))
    return rows


def main():
    m = measure()
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_mesh_offload.json")
    with open(out, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(out)}")
    print("name,value,derived")
    for name, val, derived in run(m):
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
