"""Kernel microbenchmarks (interpret-mode wall time is NOT TPU time — the
derived column reports the analytic VMEM working set + arithmetic intensity
used for the roofline argument in EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    t0 = time.monotonic()
    for _ in range(n):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.monotonic() - t0) / n * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    from repro.kernels.flash_attention.kernel import flash_attention
    B, S, H, K, hd = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
    us = _time(lambda a, b, c: flash_attention(a, b, c, block_q=128,
                                               block_k=128, interpret=True),
               q, k, v)
    vmem_kb = (128 * hd * 3 + 128 * hd) * 4 / 1024
    rows.append(("kernel/flash_attention/us_interp", us,
                 f"VMEM working set {vmem_kb:.0f}KB per (128,128) tile"))

    from repro.kernels.paged_attention.kernel import paged_attention
    P, page, pps = 64, 16, 8
    q1 = jnp.asarray(rng.standard_normal((4, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((K, P, page, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((K, P, page, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, (4, pps)), jnp.int32)
    ln = jnp.full((4,), pps * page, jnp.int32)
    us = _time(lambda *a: paged_attention(*a, interpret=True), q1, kp, vp, bt, ln)
    rows.append(("kernel/paged_attention/us_interp", us,
                 f"one page DMA per grid step: {page*hd*4/1024:.0f}KB/step"))

    from repro.kernels.kv_gather.kernel import gather_pages
    pool = jnp.asarray(rng.standard_normal((256, 32, 128)), jnp.float32)
    ids = jnp.asarray(rng.choice(256, 64, replace=False), jnp.int32)
    us = _time(lambda *a: gather_pages(*a, interpret=True), pool, ids)
    coalesced_mb = 64 * 32 * 128 * 4 / 1e6
    rows.append(("kernel/kv_gather/us_interp", us,
                 f"coalesces 64 pages -> one {coalesced_mb:.1f}MB message"))

    from repro.kernels.rwkv6_wkv.kernel import wkv6
    B, T, Hh, hdd = 1, 128, 2, 64
    r = jnp.asarray(rng.standard_normal((B, T, Hh, hdd)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((B, T, Hh, hdd)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((B, T, Hh, hdd)), jnp.float32)
    w = -jnp.asarray(rng.uniform(0.01, 1.0, (B, T, Hh, hdd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((Hh, hdd)), jnp.float32)
    s0 = jnp.zeros((B, Hh, hdd, hdd), jnp.float32)
    us = _time(lambda *a: wkv6(*a, chunk=32, interpret=True), r, kk, vv, w, u, s0)
    rows.append(("kernel/rwkv6_wkv/us_interp", us,
                 "chunked: 3 MXU matmuls + (C,C,hd) VPU pairwise per chunk"))
    return rows
