"""Fault-recovery benchmark: serving under transfer-leg faults and donor loss.

Prices what the fault-tolerance layer costs on the paper-scale analytic
clock (CodeLlama-34B on A100, CFS over fabric offload): the same request
trace runs at 0 / 5 / 20 % transfer-leg fault rates, each with ONE
donor-loss event fired at 30 % of the fault-free makespan (the donor dies
holding its fraction of the parked contexts, which recompute from the
prompt). Reports per scenario:

  * step-time p99 (scheduler-round durations — retries and recompute work
    land here; gated by scripts/check_bench_regression.py),
  * TTFT p99 and RCT p99 over all requests,
  * RCT p99 of the RECOVERED requests alone (the degrade-to-host tail),
  * leg retries absorbed and contexts reset.

Writes ``BENCH_fault_recovery.json`` next to the repo root so the perf
trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.fault_recovery
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import codellama_sim, make_requests, pct as _pct

LEG_RATES = (0.0, 0.05, 0.20)
N_REQ = 48
RATE = 40.0          # arrivals/s: enough pressure that CFS parks contexts


def _run(faults):
    from repro.core.perfmodel import A100_NVLINK
    sim = codellama_sim(A100_NVLINK, "cfs", "fabric", step_tokens=256,
                        max_running=8, faults=faults)
    res = sim.run(make_requests(rate=RATE, n=N_REQ, seed=3,
                                prompt=(300, 1200), gen=(60, 200)))
    assert all(r.finish is not None for r in res.requests)
    return sim, res


def _scenario(sim, res) -> Dict:
    ttfts = [r.ttft - r.arrival for r in res.requests]
    rcts = [r.finish - r.arrival for r in res.requests]
    rec = [r.finish - r.arrival for r in res.requests if r.recovered]
    steps = np.diff([0.0] + [e["t"] for e in res.timeline])
    return {
        "step_time_p99_s": _pct(list(steps), 0.99),
        "ttft_p99_s": _pct(ttfts, 0.99),
        "rct_p99_s": _pct(rcts, 0.99),
        "rct_recovered_p99_s": _pct(rec, 0.99) if rec else 0.0,
        "recovered_requests": int(sum(r.recovered for r in res.requests)),
        "leg_retries": int(sim.leg_retries),
        "donor_losses": int(sim.donor_losses),
        "makespan_s": float(max(r.finish for r in res.requests)),
    }


def measure() -> Dict:
    from repro.core.faults import FaultEvent, FaultInjector

    sim0, res0 = _run(None)
    t_loss = 0.3 * max(r.finish for r in res0.requests)

    out: Dict[str, Dict] = {"fault_free": _scenario(sim0, res0)}
    for rate in LEG_RATES:
        fi = FaultInjector(seed=7, leg_fault_rate=rate, events=[
            FaultEvent(kind="donor_loss", donor="d0", frac=0.5,
                       at_time=t_loss)])
        sim, res = _run(fi)
        out[f"leg_rate_{int(rate * 100)}pct"] = _scenario(sim, res)

    base = out["fault_free"]
    worst = out[f"leg_rate_{int(LEG_RATES[-1] * 100)}pct"]
    out["derived"] = {
        "makespan_overhead_at_20pct_x":
            worst["makespan_s"] / base["makespan_s"],
        "rct_p99_overhead_at_20pct_x":
            worst["rct_p99_s"] / base["rct_p99_s"],
        "all_requests_complete_under_faults": True,
    }
    return out


def run(m: Dict | None = None):
    m = m or measure()
    rows = []
    for scenario, vals in m.items():
        if scenario == "derived" or not isinstance(vals, dict):
            continue
        for k, v in vals.items():
            rows.append((f"fault_recovery/{scenario}/{k}", float(v), ""))
    for k, v in m["derived"].items():
        rows.append((f"fault_recovery/{k}", float(v),
                     "faulted vs fault-free"))
    return rows


def main():
    m = measure()
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_fault_recovery.json")
    with open(out, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(out)}")
    print("name,value,derived")
    for name, val, derived in run(m):
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
