"""Context-switch microbenchmark: page-table tier flip vs seed blob repack.

Measures what one CFS preempt+restore of a parked request actually MOVES:

  * paged runtime   — the request's KV pages flip tier via
                      ``AquaTensor.offload`` / ``ensure_local``: native-dtype
                      payload only (partial tail metered at its fill), ONE
                      coalesced message per (tier, donor) group, no repack.
  * seed blob path  — every cache leaf is sliced out of the dense decode
                      cache, upcast to float32 and packed into one staging
                      blob (``pack_context``): a ~2x byte blowup for bf16
                      KV before it even reaches the link.

    PYTHONPATH=src python -m benchmarks.context_switch
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def measure(arch: str = "qwen1.5-0.5b", ctx_len: int = 52,
            page_tokens: int = 8, max_seq: int = 64) -> Dict[str, float]:
    """Meter one preempt+restore round trip on both runtimes (bf16 model)."""
    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import REMOTE
    from repro.serving.kv_cache import (ContextStore, PagedKVRuntime,
                                        extract_slot)
    from repro.models import api

    cfg = smoke_config(get_config(arch)).replace(param_dtype="bfloat16",
                                                 compute_dtype="bfloat16")

    # --- paged runtime: park/restore are page-table tier flips -----------
    kv = PagedKVRuntime(cfg, max_seq=max_seq, page_tokens=page_tokens,
                        max_running=1)
    kv.add_remote_lease("donor0", 512 * kv.aqua.page_bytes)
    rid = 0
    kv.ensure_capacity(rid, ctx_len)
    native = kv.kv_footprint_bytes(ctx_len)

    kv.park(rid, ctx_len, prefer=REMOTE)
    paged_out_bytes = kv.meter.bytes_fabric + kv.meter.bytes_host
    paged_out_msgs = kv.meter.messages_fabric + kv.meter.messages_host
    kv.restore(rid)
    paged_rt_bytes = kv.meter.bytes_fabric + kv.meter.bytes_host
    paged_rt_msgs = kv.meter.messages_fabric + kv.meter.messages_host

    # --- seed blob path: slice every leaf, pack to one f32 blob ----------
    store = ContextStore(page_elems=2048, local_pages=4, host_pages=2048,
                         n_logical=4096)
    store.add_remote_lease("donor0", 512 * 2048 * 4)
    cache = api.init_decode_state(cfg, 1, max_seq)
    ctx = extract_slot(cache, 0, ctx_len, max_seq)
    parked = store.park(ctx, ctx_len, prefer=REMOTE)
    blob_out_bytes = store.meter.bytes_fabric + store.meter.bytes_host
    store.restore(parked)
    blob_rt_bytes = store.meter.bytes_fabric + store.meter.bytes_host

    return {
        "native_kv_bytes": float(native),
        "paged/preempt_bytes": float(paged_out_bytes),
        "paged/preempt_messages": int(paged_out_msgs),
        "paged/roundtrip_bytes": float(paged_rt_bytes),
        "paged/roundtrip_messages": int(paged_rt_msgs),
        "blob/preempt_bytes": float(blob_out_bytes),
        "blob/roundtrip_bytes": float(blob_rt_bytes),
        "blob/blowup_x": float(blob_out_bytes / native),
        "paged/overhead_x": float(paged_out_bytes / native),
    }


def run():
    m = measure()
    rows = []
    for k, v in m.items():
        note = {"blob/blowup_x": "seed path: f32 repack ~2x native bf16 KV",
                "paged/overhead_x": "<=1.0: native payload only, tail at fill",
                "paged/preempt_messages": "1 coalesced msg per (tier,donor)"}
        rows.append((f"ctxswitch/{k}", v, note.get(k, "")))
    return rows


def main():
    print("name,value,derived")
    for name, val, derived in run():
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
