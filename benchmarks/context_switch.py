"""Context-switch microbenchmark: page-table tier flip vs the seed blob
repack, for EVERY family in the zoo.

Measures what one CFS preempt+restore of a parked request actually MOVES on
the unified paged state runtime (attention KV pages, MLA latent pages, Mamba
ssm/conv and RWKV6 wkv/shift state pages):

  * paged runtime   — the request's pages flip tier via
                      ``AquaTensor.offload`` / ``ensure_local``: native-dtype
                      payload only (partial token-plane tails metered at
                      their fill), ONE coalesced message per
                      (plane, tier, donor) group, no repack.
  * blob baseline   — the DELETED seed path (PR 1's measured baseline),
                      priced analytically: every cache leaf sliced out of the
                      dense decode cache, upcast to float32 (4 bytes/element
                      regardless of native dtype) and packed into one staging
                      blob before it even reaches the link — a ~2x byte
                      blowup for bf16 state plus a full HBM gather pass
                      (``perfmodel.context_switch_time``).

Writes ``BENCH_state_paging.json`` next to the repo root (bytes moved vs
native state size and flip time vs the blob baseline, per family).

    PYTHONPATH=src python -m benchmarks.context_switch
"""
from __future__ import annotations

import json
import os
from typing import Dict

FAMILIES = {
    "attention": "qwen1.5-0.5b",
    "ssm": "rwkv6-3b",
    "mla": "deepseek-v2-lite-16b",
    "hybrid": "jamba-v0.1-52b",
}


def measure(arch: str = "qwen1.5-0.5b", ctx_len: int = 52,
            page_tokens: int = 8, max_seq: int = 64) -> Dict[str, float]:
    """Meter one preempt+restore round trip on the paged runtime (bf16 model)
    and price the deleted blob path analytically on the same footprint."""
    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import REMOTE
    from repro.core.perfmodel import TPU_V5E, context_switch_time
    from repro.serving.kv_cache import PagedStateRuntime

    cfg = smoke_config(get_config(arch)).replace(param_dtype="bfloat16",
                                                 compute_dtype="bfloat16")

    # --- paged runtime: park/restore are page-table tier flips -----------
    kv = PagedStateRuntime(cfg, max_seq=max_seq, page_tokens=page_tokens,
                           max_running=1)
    kv.add_remote_lease("donor0", 1 << 24)
    rid = 0
    kv.ensure_capacity(rid, ctx_len)
    native = kv.footprint_bytes(ctx_len)

    t0 = kv.meter.sim_time
    kv.park(rid, ctx_len, prefer=REMOTE)
    paged_out_bytes = kv.meter.bytes_fabric + kv.meter.bytes_host
    paged_out_msgs = kv.meter.messages_fabric + kv.meter.messages_host
    paged_out_time = kv.meter.sim_time - t0
    kv.restore(rid)
    paged_rt_bytes = kv.meter.bytes_fabric + kv.meter.bytes_host
    paged_rt_msgs = kv.meter.messages_fabric + kv.meter.messages_host
    paged_rt_time = kv.meter.sim_time - t0

    # --- seed blob baseline (deleted path, priced analytically) ----------
    # pack_context upcast EVERY leaf to float32: 4 bytes/element, plus the
    # full-HBM coalescing gather, as one fabric message
    blob_bytes = float(kv.footprint_elems(ctx_len)) * 4.0
    blob_time = context_switch_time(TPU_V5E, blob_bytes, tier="fabric",
                                    coalesced=True)

    return {
        "native_state_bytes": float(native),
        "paged/preempt_bytes": float(paged_out_bytes),
        "paged/preempt_messages": int(paged_out_msgs),
        "paged/preempt_time_s": float(paged_out_time),
        "paged/roundtrip_bytes": float(paged_rt_bytes),
        "paged/roundtrip_messages": int(paged_rt_msgs),
        "paged/roundtrip_time_s": float(paged_rt_time),
        "paged/overhead_x": float(paged_out_bytes / native),
        "paged/planes": len(kv.planes),
        "blob/preempt_bytes": blob_bytes,
        "blob/preempt_time_s": float(blob_time),
        "blob/blowup_x": float(blob_bytes / native),
        "flip_vs_blob_speedup_x": float(blob_time / max(paged_out_time, 1e-12)),
    }


def measure_all(ctx_len: int = 52) -> Dict[str, Dict[str, float]]:
    return {fam: measure(arch=arch, ctx_len=ctx_len)
            for fam, arch in FAMILIES.items()}


def run(results: Dict[str, Dict[str, float]] = None):
    rows = []
    for fam, m in (results or measure_all()).items():
        note = {"blob/blowup_x": "seed path: f32 repack vs native payload",
                "paged/overhead_x": "<=1.0: native payload only, tail at fill",
                "paged/preempt_messages":
                    "1 coalesced msg per (tier,donor) across ALL planes"}
        for k, v in m.items():
            rows.append((f"ctxswitch/{fam}/{k}", v, note.get(k, "")))
    return rows


def main():
    results = measure_all()
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_state_paging.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print("name,value,derived")
    for name, val, derived in run(results):
        print(f"{name},{val:.6g},{derived}")
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
