"""Global radix prefix-cache benchmark.

Measures what retaining refcount-0 prefix pages buys when sharers do NOT
overlap in time — the follow-up-turn / multi-tenant-system-prompt load
where pure live CoW sharing gets zero hits:

  * engine     — REAL numerics (smoke model, unified paged runtime): a
                 leader prefills a multi-page prompt and RUNS TO
                 COMPLETION; only then does a pack of followers with the
                 same prefix arrive. With the cache on, their adoptions
                 revive the leader's cached pages (prefill skipped, only
                 the restore is paid); off, every follower recomputes the
                 prefix from scratch. Reports cache hit/eviction counters,
                 follower TTFT, prefill chunks and step-time tails.
  * simulator  — paper scale (CodeLlama-34B on A100): a heavy-tailed
                 multi-tenant stream (Zipf tenant mix, lognormal tails,
                 bursty arrivals separated by think time) under CFS +
                 fabric offload, cache-on vs cache-off.

Writes ``BENCH_prefix_cache.json`` next to the repo root so the perf
trajectory is tracked across PRs (the step-time keys feed the perf gate).

    PYTHONPATH=src python -m benchmarks.prefix_cache
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import make_multi_tenant_requests, pct as _pct


def measure_engine(arch: str = "qwen1.5-0.5b", prefix_len: int = 24,
                   n_followers: int = 3, tail_len: int = 6,
                   max_seq: int = 64) -> Dict[str, Dict]:
    """A leader writes a ``prefix_len``-token prefix and finishes BEFORE
    ``n_followers`` twins arrive — only the refcount-0 cache can carry the
    prefix across that gap."""
    import jax
    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import HOST
    from repro.models import api
    from repro.serving.engine import ServingEngine

    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    def serve(cache: bool) -> Dict:
        rng = np.random.default_rng(12)
        prefix = list(map(int, rng.integers(0, cfg.vocab_size, prefix_len)))
        tails = [list(map(int, rng.integers(0, cfg.vocab_size, tail_len)))
                 for _ in range(n_followers)]
        eng = ServingEngine(cfg, params, max_running=2, max_seq=max_seq,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=HOST, step_tokens=16,
                            prefix_cache=cache)
        leader = eng.submit(prefix + tails[-1][:2], 6, arrival=0.0)
        while not leader.done:           # leader fully retires first
            eng.step()
        followers = [eng.submit(prefix + t, 6, arrival=eng.metrics.sim_time)
                     for t in tails]
        while eng.waiting or eng.running:
            eng.step()
        m = eng.metrics
        c = eng.kv.stats()["cache"]
        ttfts = [m.ttft[f.rid] for f in followers]
        return {
            "followers": n_followers,
            "cache_hits": c["hits"],
            "cache_hit_tokens": c["hit_tokens"],
            "cache_evictions": c["evictions"],
            "cache_demotions": c["demotions"],
            "prefill_chunks": m.prefills,
            "follower_ttft_p50_s": _pct(ttfts, 0.50),
            "follower_ttft_p99_s": _pct(ttfts, 0.99),
            "step_time_p99_s": _pct(m.step_times, 0.99),
            "sim_time_s": float(m.sim_time),
        }

    return {"cache_on": serve(True), "cache_off": serve(False)}


def measure_simulator(n: int = 80, n_tenants: int = 6,
                      gen=(40, 120)) -> Dict[str, Dict]:
    from repro.configs import get_config
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import ServingSimulator

    cfg = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2

    def run(cache: bool) -> Dict:
        reqs = make_multi_tenant_requests(n, n_tenants=n_tenants, gen=gen)
        total_prompt = sum(r.prompt_len for r in reqs)
        # capacity for a handful of full contexts: pressure keeps the
        # cache honest (it must yield, never block a real allocation)
        cap = mc.context_bytes(3072 + 256) * 6.0
        sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                               kv_capacity_bytes=cap, scheduler="cfs",
                               offload_tier="fabric", max_running=16,
                               step_tokens=512, prefix_cache=cache)
        res = sim.run(reqs)
        # followers = every request after its tenant's first arrival
        first = {}
        for r in reqs:
            first.setdefault(r.prefix_group, r.rid)
        f_ttfts = [r.ttft - r.arrival for r in res.requests
                   if r.ttft is not None and first[r.prefix_group] != r.rid]
        computed = total_prompt - sim.adopted_tokens
        return {
            "requests": len(reqs),
            "cache_hits": sim.cache_hits,
            "cache_hit_rate": sim.cache_hits / len(reqs),
            "cache_hit_tokens": sim.cache_hit_tokens,
            "prompt_tokens_total": total_prompt,
            "prefill_tokens_computed": computed,
            "follower_ttft_p50_s": _pct(f_ttfts, 0.50),
            "follower_ttft_p99_s": _pct(f_ttfts, 0.99),
            "rct_p50_s": res.p50(res.rcts()),
        }

    return {"cache_on": run(True), "cache_off": run(False)}


def measure() -> Dict:
    eng = measure_engine()
    sim = measure_simulator()
    s_on, s_off = sim["cache_on"], sim["cache_off"]
    e_on, e_off = eng["cache_on"], eng["cache_off"]
    return {
        "engine": eng,
        "simulator_34b": sim,
        "derived": {
            "engine/cache_hit_rate":
                e_on["cache_hits"] / max(e_on["followers"], 1),
            "engine/prefill_chunk_savings_x":
                e_off["prefill_chunks"] / max(e_on["prefill_chunks"], 1),
            "engine/follower_ttft_p99_improvement_x":
                e_off["follower_ttft_p99_s"]
                / max(e_on["follower_ttft_p99_s"], 1e-12),
            "sim/prefill_token_reduction_x":
                s_off["prefill_tokens_computed"]
                / max(s_on["prefill_tokens_computed"], 1),
            "sim/follower_ttft_p99_improvement_x":
                s_off["follower_ttft_p99_s"]
                / max(s_on["follower_ttft_p99_s"], 1e-12),
        },
    }


def run(m: Dict | None = None):
    m = m or measure()
    rows = []
    for variant, vals in m["engine"].items():
        for k, v in vals.items():
            rows.append((f"prefix_cache/engine/{variant}/{k}", float(v), ""))
    for variant, vals in m["simulator_34b"].items():
        for k, v in vals.items():
            rows.append((f"prefix_cache/sim/{variant}/{k}", float(v), ""))
    for k, v in m["derived"].items():
        rows.append((f"prefix_cache/{k}", float(v), "cache on vs off"))
    return rows


def main():
    m = measure()
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_prefix_cache.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
    print("name,value,derived")
    for name, val, derived in run(m):
        print(f"{name},{val:.6g},{derived}")
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
