"""Chunked continuous-batching prefill benchmark.

Measures what the chunked pipeline buys on a mixed-length burst (one long
prompt heading a pack of shorts), with and without chunking and restore
prefetch, at two scales:

  * engine     — REAL numerics (smoke model, page-native runtime): per-step
                 prefill-token bound, step-time p99, short-prompt TTFT, jit
                 trace counts across two waves of all-new prompt lengths
                 (the retrace guard's "constant in distinct lengths" claim),
                 and the prefetch overlap counters.
  * simulator  — paper scale (CodeLlama-34B on A100): TTFT p50/p99 of the
                 shorts and the max scheduler-round time, where a 6k-token
                 prefill is ~0.7 s vs a ~45 ms decode step.

Writes ``BENCH_prefill.json`` next to the repo root so the perf trajectory
is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.prefill_chunking
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import pct as _pct

STEP_TOKENS = 16


def measure_engine(arch: str = "qwen1.5-0.5b", long_len: int = 64,
                   n_short: int = 5, short_len: int = 6,
                   max_seq: int = 96) -> Dict[str, Dict]:
    import jax
    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import REMOTE
    from repro.models import api, lm
    from repro.serving.engine import ServingEngine

    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    def serve(step_tokens, prefetch, seed):
        rng = np.random.default_rng(seed)
        jax.clear_caches()            # count THIS variant's traces from zero
        lm.reset_trace_counts()
        eng = ServingEngine(cfg, params, max_running=2, max_seq=max_seq,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=REMOTE, step_tokens=step_tokens,
                            prefetch=prefetch)
        eng.pager.add_remote_lease("donor0", 2 ** 24)
        eng.submit(list(map(int, rng.integers(0, cfg.vocab_size, long_len))),
                   6, arrival=0.0)
        for _ in range(n_short):
            eng.submit(list(map(int, rng.integers(0, cfg.vocab_size,
                                                  short_len))), 6,
                       arrival=0.0)
        m = eng.run(600)
        traces_w1 = dict(lm.trace_counts())
        # wave 2: all-new distinct prompt lengths against the SAME engine
        # config — chunked buckets must add zero traces
        eng2 = ServingEngine(cfg, params, max_running=2, max_seq=max_seq,
                             scheduler="cfs", slice_tokens=3,
                             offload_tier=REMOTE, step_tokens=step_tokens,
                             prefetch=prefetch)
        eng2.pager.add_remote_lease("donor0", 2 ** 24)
        for n in (11, 23, 37, 49):
            eng2.submit(list(map(int, rng.integers(0, cfg.vocab_size, n))),
                        2, arrival=0.0)
        eng2.run(600)
        traces_w2 = dict(lm.trace_counts())
        short_ttfts = [m.ttft[r.rid] for r in eng.finished
                       if len(r.prompt_tokens) == short_len]
        return {
            "max_prefill_tokens_per_step": int(max(m.prefill_tokens_trace)),
            "step_time_p99_s": _pct(m.step_times, 0.99),
            "step_time_max_s": float(max(m.step_times)),
            "ttft_short_min_s": float(min(short_ttfts)),
            "ttft_short_p50_s": _pct(short_ttfts, 0.50),
            "ttft_short_p99_s": _pct(short_ttfts, 0.99),
            "sim_time_s": float(m.sim_time),
            "steps": m.steps,
            "preemptions": m.preemptions,
            "restores": m.restores,
            "prefetched_restores": m.prefetched_restores,
            "overlap_hidden_s": float(m.overlap_hidden_s),
            "jit_traces_prefill_wave1": traces_w1.get("serve_step", 0),
            "jit_traces_prefill_wave2": traces_w2.get("serve_step", 0),
        }

    return {
        "unchunked": serve(None, False, 7),
        "chunked": serve(STEP_TOKENS, False, 7),
        "chunked_prefetch": serve(STEP_TOKENS, True, 7),
    }


def measure_simulator(long_len: int = 6000, short_len: int = 120,
                      n_short: int = 12) -> Dict[str, Dict]:
    from repro.configs import get_config
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import Request, ServingSimulator

    cfg = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2

    def run(step_tokens, overlap):
        sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                               kv_capacity_bytes=80e9 - wb - 2e9,
                               scheduler="cfs", offload_tier="fabric",
                               max_running=8, step_tokens=step_tokens,
                               overlap_pagein=overlap)
        reqs = [Request(0, 0.0, long_len, 30)]
        reqs += [Request(i, 0.001 * i, short_len, 30)
                 for i in range(1, n_short + 1)]
        res = sim.run(reqs)
        ttfts = sorted(r.ttft - r.arrival for r in res.requests
                       if r.prompt_len == short_len)
        steps = np.diff([0.0] + [e["t"] for e in res.timeline])
        return {
            "ttft_short_p50_s": _pct(ttfts, 0.50),
            "ttft_short_p99_s": _pct(ttfts, 0.99),
            "step_time_max_s": float(steps.max()),
            "rct_p50_s": res.p50(res.rcts()),
        }

    return {
        "unchunked": run(None, False),
        "chunked": run(256, False),
        "chunked_overlap": run(256, True),
    }


def measure() -> Dict:
    eng = measure_engine()
    sim = measure_simulator()
    return {
        "engine": {"step_tokens": STEP_TOKENS, **eng},
        "simulator_34b": {"step_tokens": 256, **sim},
        "derived": {
            # the smoke model is decode-bound (weight read >> prefill FLOPs),
            # so the engine's time-domain win shows on the FIRST token; the
            # p50/p99 wins show at paper scale where prefill dominates a step
            "engine/ttft_short_first_improvement_x":
                eng["unchunked"]["ttft_short_min_s"]
                / eng["chunked_prefetch"]["ttft_short_min_s"],
            "sim/ttft_short_p99_improvement_x":
                sim["unchunked"]["ttft_short_p99_s"]
                / sim["chunked_overlap"]["ttft_short_p99_s"],
            "sim/step_time_max_reduction_x":
                sim["unchunked"]["step_time_max_s"]
                / sim["chunked"]["step_time_max_s"],
            "engine/jit_traces_flat_across_new_lengths":
                eng["chunked"]["jit_traces_prefill_wave2"]
                == eng["chunked"]["jit_traces_prefill_wave1"],
        },
    }


def run(m: Dict | None = None):
    m = m or measure()
    rows = []
    for variant, vals in m["simulator_34b"].items():
        if not isinstance(vals, dict):
            continue
        for k, v in vals.items():
            rows.append((f"prefill/{variant}/{k}", v, ""))
    for k, v in m["derived"].items():
        rows.append((f"prefill/{k}", float(v), "chunked vs whole-prompt"))
    return rows


def main():
    m = measure()
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_prefill.json")
    with open(out, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(out)}")
    print("name,value,derived")
    for name, val, derived in run(m):
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
