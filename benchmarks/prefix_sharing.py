"""Copy-on-write prefix-sharing benchmark.

Measures what refcounted page sharing buys on a shared-prefix burst (the
many-users-one-system-prompt load the paper targets) at two scales:

  * engine     — REAL numerics (smoke model, unified paged runtime): a
                 leader prefills a multi-page prompt prefix, then a pack of
                 followers with the same prefix arrives. With sharing, each
                 follower's block tables adopt the leader's physical pages
                 and its chunked prefill starts past the prefix. Reports
                 peak physical pages, follower TTFT, park/restore bytes
                 under CFS preemption pressure, and the CoW/adoption
                 counters — against the identical run with sharing off.
  * simulator  — paper scale (CodeLlama-34B on A100): 12 users sharing a
                 2k-token system prompt under CFS + fabric offload; prefix
                 groups dedup admission bytes and tier-flip costs
                 (``ModelCost.unique_context_bytes``).

Writes ``BENCH_prefix_sharing.json`` next to the repo root so the perf
trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.prefix_sharing
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import pct as _pct


def measure_engine(arch: str = "qwen1.5-0.5b", prefix_len: int = 24,
                   n_followers: int = 3, tail_len: int = 6,
                   max_seq: int = 64) -> Dict[str, Dict]:
    """One leader + ``n_followers`` sharing a ``prefix_len``-token prompt
    prefix (>= 2 pages), with CFS preemption pressure so parked shared
    prefixes exercise the move-once refcount path."""
    import jax
    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import REMOTE
    from repro.models import api
    from repro.serving.engine import ServingEngine

    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    def serve(sharing: bool) -> Dict:
        rng = np.random.default_rng(11)
        prefix = list(map(int, rng.integers(0, cfg.vocab_size, prefix_len)))
        tails = [list(map(int, rng.integers(0, cfg.vocab_size, tail_len)))
                 for _ in range(n_followers)]
        eng = ServingEngine(cfg, params, max_running=2, max_seq=max_seq,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=REMOTE, step_tokens=16,
                            prefix_sharing=sharing)
        eng.pager.add_remote_lease("donor0", 1 << 24)
        leader = eng.submit(prefix + tails[-1][:2], 6, arrival=0.0)
        # leader prefills (and registers) the prefix before the burst lands
        while not leader.prefilled and not leader.done:
            eng.step()
        followers = [eng.submit(prefix + t, 6, arrival=eng.metrics.sim_time)
                     for t in tails]
        peak_pages = sum(eng.kv.physical_pages().values())
        while eng.waiting or eng.running:
            eng.step()
            peak_pages = max(peak_pages,
                             sum(eng.kv.physical_pages().values()))
        m = eng.metrics
        sh = eng.kv.stats()["sharing"]
        # EngineMetrics.ttft is already arrival-relative
        ttfts = [m.ttft[f.rid] for f in followers]
        return {
            "peak_physical_pages": int(peak_pages),
            "follower_ttft_p50_s": _pct(ttfts, 0.50),
            "follower_ttft_max_s": float(max(ttfts)),
            "park_restore_bytes": float(eng.kv.meter.bytes_fabric
                                        + eng.kv.meter.bytes_host),
            "preemptions": m.preemptions,
            "prefill_chunks": m.prefills,
            "prefix_hits": sh["prefix_hits"],
            "adopted_tokens": sh["adopted_tokens"],
            "cow_copies": sh["cow_copies"],
            "sim_time_s": float(m.sim_time),
        }

    shared = serve(True)
    unshared = serve(False)
    return {"shared": shared, "unshared": unshared}


def measure_simulator(system_prompt: int = 2048, tail: int = 128,
                      n_users: int = 12, gen: int = 60) -> Dict[str, Dict]:
    from repro.configs import get_config
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import Request, ServingSimulator

    cfg = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2

    def run(shared: bool) -> Dict:
        # capacity for only a few full contexts: admission headroom is the
        # variable prefix sharing raises
        cap = mc.context_bytes(system_prompt + tail + gen) * 3.5
        sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                               kv_capacity_bytes=cap, scheduler="cfs",
                               offload_tier="fabric", max_running=16,
                               step_tokens=512)
        # the first user writes the system prompt; the burst arrives once
        # it is prefilled (adoption happens at arrival, as in the engine)
        reqs = [Request(0, 0.0, system_prompt + tail, gen,
                        prefix_group=0 if shared else None,
                        shared_prefix_len=system_prompt if shared else 0)]
        reqs += [Request(i, 2.5 + 0.01 * i, system_prompt + tail, gen,
                         prefix_group=0 if shared else None,
                         shared_prefix_len=system_prompt if shared else 0)
                 for i in range(1, n_users)]
        res = sim.run(reqs)
        ttfts = res.ttfts()
        running_peak = max((e["running"] for e in res.timeline), default=0)
        return {"ttft_p50_s": _pct(ttfts, 0.50),
                "ttft_p99_s": _pct(ttfts, 0.99),
                "rct_p50_s": res.p50(res.rcts()),
                "peak_concurrent": int(running_peak)}

    return {"shared": run(True), "unshared": run(False)}


def measure() -> Dict:
    eng = measure_engine()
    sim = measure_simulator()
    e_s, e_u = eng["shared"], eng["unshared"]
    return {
        "engine": eng,
        "simulator_34b": sim,
        "derived": {
            # the smoke model is decode-bound (weight read >> prefill
            # FLOPs), so the engine's TTFT ratio mostly reflects the larger
            # shared run set; the time-domain win shows at paper scale
            # where the skipped prefill dominates (sim/ rows)
            "engine/physical_page_savings_x":
                e_u["peak_physical_pages"] / max(e_s["peak_physical_pages"], 1),
            "engine/follower_ttft_p50_improvement_x":
                e_u["follower_ttft_p50_s"] / max(e_s["follower_ttft_p50_s"],
                                                 1e-12),
            "engine/park_restore_bytes_savings_x":
                e_u["park_restore_bytes"] / max(e_s["park_restore_bytes"],
                                                1e-9),
            "sim/ttft_p99_improvement_x":
                sim["unshared"]["ttft_p99_s"]
                / max(sim["shared"]["ttft_p99_s"], 1e-12),
            "sim/peak_concurrent_gain":
                sim["shared"]["peak_concurrent"]
                - sim["unshared"]["peak_concurrent"],
        },
    }


def run(m: Dict | None = None):
    m = m or measure()
    rows = []
    for variant, vals in m["engine"].items():
        for k, v in vals.items():
            rows.append((f"prefix/engine/{variant}/{k}", float(v), ""))
    for variant, vals in m["simulator_34b"].items():
        for k, v in vals.items():
            rows.append((f"prefix/sim/{variant}/{k}", float(v), ""))
    for k, v in m["derived"].items():
        rows.append((f"prefix/{k}", float(v), "shared vs unshared"))
    return rows


def main():
    m = measure()
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_prefix_sharing.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
    print("name,value,derived")
    for name, val, derived in run(m):
        print(f"{name},{val:.6g},{derived}")
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
