"""One function per paper figure/table (§Per-experiment index in DESIGN.md).

Each returns rows: (name, value, derived-description). Values that reproduce
a paper claim carry the paper's number in the description for comparison.
All serving figures run on BOTH hardware profiles: the paper's A100/NVLink
testbed (claim fidelity) and the TPU v5e port (DESIGN.md §2 scaling).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import codellama_sim, make_requests, pct
from repro.configs import get_config
from repro.core.control_loop import BatchInformer, LLMInformer
from repro.core.coordinator import Coordinator
from repro.core.perfmodel import A100_NVLINK, TPU_V5E, ModelCost
from repro.core.placer import ModelSpec, place
from repro.core.simulator import (Request, ServingSimulator,
                                  long_prompt_tokens_per_s)

HWS = [A100_NVLINK, TPU_V5E]


# ---------------------------------------------------------------------------
def fig1_responsiveness():
    """Fig 1: TTFT/RCT of batch (vLLM) vs CFS vs CFS+AQUA under 5 req/s."""
    rows = []
    for hw in HWS:
        for name, sched, tier in [("vllm", "vllm", "host"),
                                  ("cfs-pcie", "cfs", "host"),
                                  ("cfs-aqua", "cfs", "fabric")]:
            sim = codellama_sim(hw, sched, tier)
            res = sim.run(make_requests(5.0, 80))
            rows.append((f"fig1/{hw.name}/{name}/ttft_p90_s",
                         pct(res.ttfts(), 0.9), "paper fig1a: aqua ~4x below vllm"))
            rows.append((f"fig1/{hw.name}/{name}/rct_p50_s",
                         pct(res.rcts(), 0.5), "paper fig1b: cfs-pcie ~+50-100%, aqua recovers"))
    return rows


def fig2_contention():
    """Fig 2: free memory at peak throughput: compute- vs memory-bound."""
    rows = []
    hbm = 80e9
    # compute-bound models: throughput saturates with tens of GB free
    for name, working in [("audiogen", 42e9), ("stable-diffusion", 38e9)]:
        rows.append((f"fig2/{name}/free_gb_at_peak", (hbm - working) / 1e9,
                     "paper fig2a/b: 10s of GB free at peak throughput"))
    llama = ModelCost.from_config(get_config("aqua-llama2-13b"))
    wb = get_config("aqua-llama2-13b").param_count() * 2
    batch = 0
    free = hbm - wb
    while free > llama.kv_bytes(1100):      # mean ctx ~1100 tokens
        batch += 1
        free -= llama.kv_bytes(1100)
    rows.append(("fig2/llama2-13b/free_gb_at_peak", free / 1e9,
                 "paper fig2c: ~0 free at peak (memory-bound)"))
    rows.append(("fig2/llama2-13b/peak_batch", batch, "kv-limited batch size"))
    return rows


def fig3_bandwidth():
    """Fig 3a: interconnect effective bandwidth vs message size."""
    rows = []
    for hw in HWS:
        for s in (64e3, 2e6, 64e6, 1e9):
            rows.append((f"fig3a/{hw.name}/fabric_gbps_at_{int(s/1e3)}KB",
                         hw.fabric.effective_bw(s) / 1e9,
                         "paper: ~100 GB/s @2MB, ~250 peak (NVLink A100)"))
        rows.append((f"fig3a/{hw.name}/host_gbps_large",
                     hw.host_link.effective_bw(1e9) / 1e9, "PCIe roofline"))
    return rows


def fig7_long_prompt():
    """Fig 7: long-prompt (8k tokens, OPT-30B) throughput vs FlexGen."""
    rows = []
    cfg = get_config("aqua-opt-30b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2
    for hw in HWS:
        free = max(hw.hbm_bytes - wb - 12e9, 2e9)
        th = {}
        for tier in ("host", "fabric"):
            th[tier] = long_prompt_tokens_per_s(
                hw, mc, ctx_tokens=8000, free_hbm_bytes=free,
                weight_bytes=min(wb, hw.hbm_bytes * 0.8), tier=tier)
            rows.append((f"fig7/{hw.name}/{tier}_tok_s", th[tier],
                         "10-min token count ratio is the paper metric"))
        rows.append((f"fig7/{hw.name}/speedup_x", th["fabric"] / th["host"],
                     "paper: 6x on A100/NVLink"))
    return rows


def fig8_fig12_lora():
    """Fig 8/12: LoRA adapter RCTs; larger adapters benefit more."""
    rows = []
    for hw in HWS:
        for size, tag in [(160e6, "160MB"), (320e6, "320MB")]:
            rcts = {}
            for tier in ("host", "fabric"):
                # paper fig12 setup: 200 adapters, 10 GB reserved cache,
                # a different adapter per prompt, 10 req/s, short outputs
                sim = codellama_sim(hw, "vllm", tier, lora_cache_bytes=10e9,
                                    lora_num_adapters=200)
                reqs = make_requests(10.0, 100, prompt=(100, 300),
                                     gen=(5, 40), lora_bytes=size)
                res = sim.run(reqs)
                rcts[tier] = (pct(res.rcts(), 0.5), pct(res.rcts(), 0.1))
            rows.append((f"fig12/{hw.name}/{tag}/rct_ratio_p50",
                         rcts["host"][0] / rcts["fabric"][0],
                         "paper fig8: up to 1.8x lower RCT (sorted curves diverge at the short end)"))
            rows.append((f"fig12/{hw.name}/{tag}/rct_ratio_short",
                         rcts["host"][1] / rcts["fabric"][1],
                         "paper fig12: bigger adapter => bigger win"))
    return rows


def fig9_cfs():
    """Fig 9: CFS responsiveness at 2 and 5 req/s."""
    rows = []
    for hw in HWS:
        for rate in (2.0, 5.0):
            ttfts = {}
            rcts = {}
            for name, sched, tier in [("vllm", "vllm", "host"),
                                      ("aqua", "cfs", "fabric")]:
                sim = codellama_sim(hw, sched, tier)
                res = sim.run(make_requests(rate, 60, seed=int(rate)))
                ttfts[name] = pct(res.ttfts(), 0.9)
                rcts[name] = pct(res.rcts(), 0.5)
            rows.append((f"fig9/{hw.name}/{rate:.0f}rps/ttft_improvement_x",
                         ttfts["vllm"] / ttfts["aqua"], "paper: ~4x TTFT"))
            rows.append((f"fig9/{hw.name}/{rate:.0f}rps/rct_ratio",
                         rcts["aqua"] / rcts["vllm"],
                         "paper fig13: <=1.2x worst case"))
    return rows


def fig10_elastic():
    """Fig 10: elastic lease/reclaim timeline (llm-informer driven)."""
    rows = []
    hw = A100_NVLINK
    coord = Coordinator(strict_pairing=False)
    informer = LLMInformer("llama2-13b", coord, total_bytes=40e9,
                           reserve_bytes=5e9, low_rate=2.0, high_rate=4.0,
                           window=4)
    cfg = get_config("aqua-opt-30b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2
    free = max(hw.hbm_bytes - wb - 12e9, 2e9)

    phases = [("low_traffic", 1, 8), ("spike", 5, 8), ("recovered", 1, 8)]
    donated = 0.0
    for label, rate, ticks in phases:
        for _ in range(ticks):
            d = informer.inform_stats(pending_requests=int(rate),
                                      kv_utilization=0.2 if rate < 3 else 0.9)
            if d.donate:
                donated = -d.delta_bytes
                coord.allocate("opt-30b", donated)
            if d.reclaim and d.delta_bytes == 0.0:
                # consumer must release before reclaim completes
                coord.free("opt-30b", "llama2-13b", donated)
                donated = 0.0
        tier = "fabric" if (donated and rate < 3) else "host"
        th = long_prompt_tokens_per_s(hw, mc, ctx_tokens=8000,
                                      free_hbm_bytes=free, weight_bytes=wb,
                                      tier=tier)
        rows.append((f"fig10/{label}/consumer_tok_s", th,
                     "paper fig10b: 6x during donation, dip on reclaim, recovers"))
    return rows


def fig11_producer_overhead():
    """Fig 3b/11: donating memory costs the producer <5% throughput."""
    rows = []
    for hw in HWS:
        cfg = get_config("aqua-llama2-13b")
        mc = ModelCost.from_config(cfg)
        wb = cfg.param_count() * 2
        base = mc.decode_step_time(hw, 16, 1000, wb)
        # donation overhead: consumer's paging stream steals HBM bandwidth
        # for the duration of the copy; fabric stream ~ fabric_bw/hbm_bw
        overhead = hw.fabric.peak_bw / hw.hbm_bw
        rows.append((f"fig11/{hw.name}/producer_slowdown_pct",
                     100 * overhead * 0.3,      # paging duty cycle <= 30%
                     "paper fig3b/fig11: <5% (GPU cores mostly idle during IO)"))
        rows.append((f"fig11/{hw.name}/decode_step_ms", base * 1e3, "baseline"))
    return rows


def fig13_chatbot():
    """Fig 13: multi-turn chatbot, 25 users, 4 turns — long-term fairness."""
    rows = []
    for hw in HWS:
        rng = np.random.default_rng(7)
        for name, sched, tier in [("vllm", "vllm", "host"),
                                  ("cfs-pcie", "cfs", "host"),
                                  ("aqua", "cfs", "fabric")]:
            all_rcts = []
            t0 = 0.0
            for turn in range(4):
                reqs = [Request(u + 100 * turn, t0 + float(rng.exponential(2.0)),
                                int(rng.integers(300, 900)),
                                int(rng.integers(100, 300)))
                        for u in range(25)]
                sim = codellama_sim(hw, sched, tier)
                res = sim.run(reqs)
                all_rcts += res.rcts()
                t0 += max(res.rcts()) if res.rcts() else 30.0
            rows.append((f"fig13/{hw.name}/{name}/rct_p90_s",
                         pct(all_rcts, 0.9),
                         "paper: cfs-pcie +50%; aqua <=20% over vllm worst-case"))
    return rows


def fig14_placer():
    """Fig 14 / A.1: placer convergence time, 16-128 GPUs."""
    rows = []
    rng = np.random.default_rng(0)
    for n_gpus in (16, 32, 64, 128):
        servers = n_gpus // 8
        # mixed modalities: 1/3 image, 1/3 audio (producers), 1/3 llm
        models = []
        per = n_gpus // 3
        for i in range(per):
            models.append(ModelSpec(f"img{i}", 30.0, "producer"))
            models.append(ModelSpec(f"aud{i}", 40.0, "producer"))
            models.append(ModelSpec(f"llm{i}", -35.0, "consumer"))
        models = models[:n_gpus - 1]
        p = place(models, servers, 8, 80.0,
                  solver="milp" if n_gpus <= 32 else "greedy")
        rows.append((f"fig14/mixed/{n_gpus}gpus/solve_s", p.solve_time,
                     f"paper: <45s at 128 GPUs ({p.solver})"))
        # 50/50 llm producers/consumers converge much faster (paper A.1)
        models = ([ModelSpec(f"p{i}", 30.0, "producer") for i in range(n_gpus // 2)]
                  + [ModelSpec(f"c{i}", -30.0, "consumer") for i in range(n_gpus // 2 - 1)])
        p = place(models, servers, 8, 80.0, solver="bnb")
        rows.append((f"fig14/llm5050/{n_gpus}gpus/solve_s", p.solve_time,
                     "paper: <1s (exchangeable types)"))
    return rows


ALL_FIGURES = [fig1_responsiveness, fig2_contention, fig3_bandwidth,
               fig7_long_prompt, fig8_fig12_lora, fig9_cfs, fig10_elastic,
               fig11_producer_overhead, fig13_chatbot, fig14_placer]
