"""One-launch fused engine step benchmark.

Measures what fusing the whole engine step into ONE jitted call buys over
the per-request path (one call per admitted request's chunk + one decode
call), at two scales:

  * engine     — REAL numerics (smoke model, fused runtime): launches/step
                 actually issued vs the per-request baseline's launch count
                 for the SAME packed work (recorded per step), step-time
                 p50/p99 on the analytic clock, speculative chunk-ahead
                 counters, and the fused entry point's jit trace count
                 across two waves (flat in request count).
  * simulator  — paper scale (CodeLlama-34B on A100): step-time p50/p99 and
                 decode-lane throughput at 1-64 concurrent requests, fused
                 vs per-request launch pricing (``ModelCost.launch_time``).

The headline claims (the PR's acceptance criteria): launches/step collapse
to O(1) in admitted requests, and step-time p99 is no worse than the
per-request baseline at 16+ concurrent requests.

Writes ``BENCH_fused_step.json`` next to the repo root so the perf
trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.fused_step
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import pct as _pct

STEP_TOKENS = 16
SIM_CONCURRENCY = (1, 2, 4, 8, 16, 32, 64)


def measure_engine(arch: str = "qwen1.5-0.5b", n_requests: int = 12,
                   max_seq: int = 96) -> Dict[str, Dict]:
    import jax
    from repro.configs import get_config, smoke_config
    from repro.core.aqua_tensor import REMOTE
    from repro.models import api, lm
    from repro.serving.engine import ServingEngine

    cfg = smoke_config(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    lengths = [int(rng.integers(5, 40)) for _ in range(n_requests)]

    def serve(lens, spec):
        eng = ServingEngine(cfg, params, max_running=4, max_seq=max_seq,
                            scheduler="cfs", slice_tokens=3,
                            offload_tier=REMOTE, step_tokens=STEP_TOKENS,
                            spec_chunk_ahead=spec)
        eng.pager.add_remote_lease("donor0", 2 ** 24)
        for n in lens:
            eng.submit(list(map(int, rng.integers(0, cfg.vocab_size, n))),
                       4, arrival=0.0)
        m = eng.run(2000)
        assert len(eng.finished) == len(lens)
        return eng, m

    jax.clear_caches()
    lm.reset_trace_counts()
    _, m = serve(lengths, True)
    traces_w1 = lm.trace_counts().get("serve_step", 0)
    # wave 2: 2x the requests, all-new lengths — zero new traces
    serve([int(rng.integers(5, 40)) for _ in range(2 * n_requests)], True)
    traces_w2 = lm.trace_counts().get("serve_step", 0)
    _, m_nospec = serve(lengths, False)

    busy = [i for i, l in enumerate(m.launch_trace) if l > 0]
    return {
        "fused": {
            "launches_per_step_max": int(max(m.launch_trace)),
            "launches_per_step_mean": float(np.mean(
                [m.launch_trace[i] for i in busy])),
            "step_time_p50_s": _pct(m.step_times, 0.50),
            "step_time_p99_s": _pct(m.step_times, 0.99),
            "sim_time_s": float(m.sim_time),
            "steps": m.steps,
            "prefill_chunk_rows": m.prefills,
            "spec_chunks": m.spec_chunks,
            "spec_tokens": m.spec_tokens,
            "jit_traces_wave1": traces_w1,
            "jit_traces_wave2": traces_w2,
        },
        "per_request_baseline": {
            # the launch count the SAME packed work would have paid on the
            # per-request path (one call per chunk row + one decode call),
            # recorded step by step while the fused engine ran
            "launches_per_step_max": int(max(m.baseline_launch_trace)),
            "launches_per_step_mean": float(np.mean(
                [m.baseline_launch_trace[i] for i in busy])),
        },
        "no_speculation": {
            "sim_time_s": float(m_nospec.sim_time),
            "spec_chunks": m_nospec.spec_chunks,
        },
    }


def measure_simulator(prompt_len: int = 800, gen_len: int = 40
                      ) -> Dict[str, Dict]:
    from repro.configs import get_config
    from repro.core.perfmodel import A100_NVLINK, ModelCost
    from repro.core.simulator import Request, ServingSimulator

    cfg = get_config("aqua-codellama-34b")
    mc = ModelCost.from_config(cfg)
    wb = cfg.param_count() * 2

    def run(fused, n):
        sim = ServingSimulator(A100_NVLINK, mc, weight_bytes=wb,
                               kv_capacity_bytes=80e9 - wb - 2e9,
                               scheduler="cfs", offload_tier="fabric",
                               max_running=n, step_tokens=256,
                               fused_step=fused)
        reqs = [Request(i, 0.0005 * i, prompt_len, gen_len)
                for i in range(n)]
        res = sim.run(reqs)
        steps = np.diff([0.0] + [e["t"] for e in res.timeline])
        makespan = max(r.finish for r in res.requests)
        return {
            "step_time_p50_s": _pct(list(steps), 0.50),
            "step_time_p99_s": _pct(list(steps), 0.99),
            "decode_tokens_per_s": float(n * gen_len / makespan),
            "makespan_s": float(makespan),
            # launches per engine STEP: fused = n_layers; baseline adds one
            # call per granted chunk of the step's run set
            "launches_per_step": mc.n_layers if fused else None,
        }

    out: Dict[str, Dict] = {}
    for n in SIM_CONCURRENCY:
        out[f"c{n:02d}"] = {
            "concurrent": n,
            "fused": run(True, n),
            "per_request": run(False, n),
        }
    return out


def measure() -> Dict:
    eng = measure_engine()
    sim = measure_simulator()
    at16 = sim["c16"]
    at64 = sim["c64"]
    return {
        "engine": {"step_tokens": STEP_TOKENS, **eng},
        "simulator_34b": {"step_tokens": 256, **sim},
        "derived": {
            # launches/step: O(1) fused vs O(admitted requests) baseline
            "engine/launch_collapse_x":
                eng["per_request_baseline"]["launches_per_step_max"]
                / eng["fused"]["launches_per_step_max"],
            "engine/jit_traces_flat_across_request_counts":
                eng["fused"]["jit_traces_wave2"]
                == eng["fused"]["jit_traces_wave1"],
            "sim/p99_no_worse_at_16":
                at16["fused"]["step_time_p99_s"]
                <= at16["per_request"]["step_time_p99_s"],
            "sim/p99_improvement_x_at_64":
                at64["per_request"]["step_time_p99_s"]
                / at64["fused"]["step_time_p99_s"],
            "sim/decode_throughput_gain_at_64":
                at64["fused"]["decode_tokens_per_s"]
                / at64["per_request"]["decode_tokens_per_s"],
        },
    }


def run(m: Dict | None = None):
    m = m or measure()
    rows = []
    for key, cell in m["simulator_34b"].items():
        if not isinstance(cell, dict):
            continue
        for variant in ("fused", "per_request"):
            for k, v in cell[variant].items():
                if v is not None:
                    rows.append((f"fused_step/{key}/{variant}/{k}", v, ""))
    for k, v in m["derived"].items():
        rows.append((f"fused_step/{k}", float(v),
                     "fused vs per-request step"))
    return rows


def main():
    m = measure()
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_fused_step.json")
    with open(out, "w") as f:
        json.dump(m, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(out)}")
    print("name,value,derived")
    for name, val, derived in run(m):
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
