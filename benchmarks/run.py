"""Benchmark harness: one function per paper table/figure.

Prints ``name,value,derived`` CSV (value is seconds / GB/s / ratio as the
name indicates; ``us_per_call`` rows come from kernel_bench).
Usage:  PYTHONPATH=src python -m benchmarks.run [--skip-kernels]
                                                [--skip-lifecycle]
"""
from __future__ import annotations

import sys


def main() -> None:
    import benchmarks.figures as F
    rows = []
    for fig in F.ALL_FIGURES:
        try:
            rows += fig()
        except Exception as e:  # a failing figure must not hide the others
            rows.append((f"{fig.__name__}/ERROR", float("nan"), repr(e)[:80]))
    if "--skip-kernels" not in sys.argv:
        from benchmarks.kernel_bench import run as krun
        rows += krun()
    if "--skip-lifecycle" not in sys.argv:
        from benchmarks.lifecycle import run as lrun
        rows += lrun()
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.6g},{derived}")


if __name__ == "__main__":
    main()
