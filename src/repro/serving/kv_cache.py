"""ContextStore: per-request dynamic context blobs on AQUA TENSORS.

The engine's batched decode cache holds the *running* requests. When the CFS
scheduler preempts a request, its whole-stack context (every cache leaf's
slice for that batch slot, truncated to the request's length) is packed into
one contiguous blob, chunked into fixed-size pages, and handed to an
AquaTensor — which places the pages LOCAL / REMOTE(fabric) / HOST and meters
the movement. Packing across all layers at once is exactly the paper's
coalescing fix ("gathering smaller tensors into a temporary tensor ... and
copying that to the offloaded tensor", §5).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aqua_tensor import AquaTensor, REMOTE, TransferMeter


def _is_seq_leaf(leaf, max_seq: int) -> bool:
    return leaf.ndim >= 3 and leaf.shape[2] == max_seq


def extract_slot(cache, slot: int, ctx_len: int, max_seq: int):
    """Slice one request's context out of the batched cache pytree."""
    def f(leaf):
        if _is_seq_leaf(leaf, max_seq):
            return leaf[:, slot, :ctx_len]
        return leaf[:, slot]
    return jax.tree.map(f, cache)


def insert_slot(cache, ctx, slot: int, ctx_len: int, max_seq: int):
    """Write a request's context back into the batched cache at `slot`."""
    def f(leaf, part):
        if _is_seq_leaf(leaf, max_seq):
            return leaf.at[:, slot, :ctx_len].set(part.astype(leaf.dtype))
        return leaf.at[:, slot].set(part.astype(leaf.dtype))
    return jax.tree.map(f, cache, ctx)


def pack_context(ctx) -> Tuple[jnp.ndarray, List[Tuple[tuple, Any]]]:
    """Flatten a context pytree into one f32 vector + restore metadata."""
    leaves = jax.tree.leaves(ctx)
    meta = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    return flat, meta


def unpack_context(flat: jnp.ndarray, meta, treedef):
    parts = []
    off = 0
    for shape, dtype in meta:
        n = int(np.prod(shape))
        parts.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, parts)


@dataclass
class ParkedContext:
    page_ids: np.ndarray
    n_elems: int
    meta: list
    treedef: Any
    ctx_len: int


class ContextStore:
    """Pages parked request contexts into an AquaTensor."""

    def __init__(self, *, page_elems: int = 32768, local_pages: int = 64,
                 host_pages: int = 4096, n_logical: int = 8192,
                 meter: Optional[TransferMeter] = None):
        self.page_elems = page_elems
        self.aqua = AquaTensor(n_logical=n_logical, page_shape=(page_elems,),
                               local_slots=local_pages, host_slots=host_pages,
                               dtype=jnp.float32, meter=meter, name="ctx")

    # -- coordinator-driven lease plumbing --------------------------------
    def add_remote_lease(self, donor: str, nbytes: float):
        slots = max(1, int(nbytes // (self.page_elems * 4)))
        self.aqua.add_remote_lease(donor, slots)

    def evict_remote(self, donor: str) -> int:
        return self.aqua.evict_remote(donor)

    # -- park / restore ----------------------------------------------------
    def park(self, ctx, ctx_len: int, *, prefer: int = REMOTE) -> ParkedContext:
        flat, meta = pack_context(ctx)       # the coalescing gather
        treedef = jax.tree.structure(ctx)
        n_pages = math.ceil(flat.size / self.page_elems)
        pad = n_pages * self.page_elems - flat.size
        flat = jnp.pad(flat, (0, pad))
        lps = self.aqua.allocate(n_pages, prefer=prefer)
        self.aqua.write(lps, flat.reshape(n_pages, self.page_elems))
        return ParkedContext(lps, flat.size - pad, meta, treedef, ctx_len)

    def restore(self, parked: ParkedContext):
        pages = self.aqua.read(parked.page_ids, meter=True)
        flat = pages.reshape(-1)[: parked.n_elems]
        ctx = unpack_context(flat, parked.meta, parked.treedef)
        self.aqua.free(parked.page_ids)
        return ctx

    def stats(self) -> Dict:
        return {"tiers": self.aqua.tier_counts(),
                "meter": {"bytes_fabric": self.aqua.meter.bytes_fabric,
                          "bytes_host": self.aqua.meter.bytes_host,
                          "sim_time": self.aqua.meter.sim_time}}
