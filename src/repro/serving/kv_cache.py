"""Unified paged state runtime: EVERY family's dynamic context on AquaTensor
pages, behind per-request block tables.

``PagedStateRuntime`` is the serving engine's state manager (paper §3 + §5
made structural, for the paper's whole model zoo): each family's per-request
dynamic context is decomposed by ``models/lm.py:paged_layout`` into page
PLANES — one tiered AquaTensor pool per plane, native-dtype payloads:

    kv     (2, n_kv, page, hd)   attention K/V, ceil(ctx/page) pages/layer
    mla    (page, kv_lora+rope)  fused MLA latent + roped key, token-paged
    ssm    (d_inner, d_state)    Mamba SSM state (f32), one page/layer
    conv   (d_conv-1, d_inner)   Mamba conv tail, one page/layer
    wkv    (H, hd, hd)           RWKV6 wkv state (f32), one page/layer
    shift  (2, d_model)          RWKV6 time/channel-mix shifts, one page/layer

A hybrid (Jamba) request owns kv pages for its attention sub-layers and
ssm/conv pages for the Mamba ones; an RWKV6 request owns only fixed-size
state pages (O(1) context). Decode/prefill read and write the LOCAL pools
directly inside the jit'd whole-step programs (attention through the
``kernels/paged_attention`` block-table kernels, MLA/recurrent planes via
shape-stable jnp gathers/scatters), so preemption is a *page-table tier
flip* for every family:

    park    = offload(pages)      one coalesced message per (tier, donor)
    restore = ensure_local(pages) group across ALL planes of the request

— no gather of cache leaves, no float32 blob, no repacking, for ANY family.
Partial token-plane tails are metered at their valid fraction, so a parked
request moves exactly its native-dtype context footprint. The seed-era dense
blob-store shim this replaces is deleted; there is exactly one way a
request's state moves between tiers.

PREFIX SHARING (copy-on-write): the same by-reference insight applies
*within* the resident tier. A prefix index (hash chain over page-aligned
prompt token blocks) lets ``adopt_prefix`` map a new request's block tables
onto the physical pages another request already wrote for the same prompt
prefix — the sharer skips those chunks in the chunked-prefill pipeline and
its first chunk starts past the shared prefix. Shared pages are refcounted
in the AquaTensor (``page_refs``), pinned LOCAL while any referencer is
active, moved between tiers ONCE however many block tables point at them,
and copied on write (``make_writable``) the moment a sharer must write into
one (recomputing the final prompt position of a fully-matched prompt, or a
decode append landing in a shared tail). Sharing is enabled only when every
plane is ``shareable`` (token planes: position-addressed, immutable once
written); families with recurrent state planes opt out — a state page
summarizes the whole prefix and is rewritten every step.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import (AquaTensor, LOCAL, REMOTE, TransferMeter)
from repro.core.errors import LeaseRevokedError


@dataclass
class _Plane:
    """One page plane: an AquaTensor pool + the per-request page bookkeeping."""
    name: str
    kind: str                        # "tokens" | "state"
    aqua: AquaTensor
    n_layers: int                    # plane layers across the whole stack
    n_sub: int                       # plane sub-layers per group
    token_bytes: int = 0             # per-layer bytes/token (token planes)
    scratch_lp: int = 0
    pages: Dict[int, List[List[int]]] = field(default_factory=dict)
    # LOCAL pin count per logical page: how many ACTIVE (unparked)
    # requests reference it. park() may only offload pages whose pin
    # reaches zero — a shared prefix page stays LOCAL while any sharer
    # still runs, and moves tiers exactly once when the last sharer parks.
    pin: Dict[int, int] = field(default_factory=dict)

    @property
    def scratch_slot(self) -> int:
        return int(self.aqua.page_table[self.scratch_lp, 1])

    def flat(self, rid: int) -> np.ndarray:
        return np.asarray([lp for row in self.pages.get(rid, [])
                           for lp in row], np.int64)


def _hash_blocks(tokens: Sequence[int], page_tokens: int,
                 seed: object = None) -> List[int]:
    """Chain-hash a prompt's FULL page-aligned token blocks: entry ``i``
    identifies the whole prefix ``tokens[:(i+1)*page_tokens]`` (each link
    hashes the previous link plus its own block), so a single dict lookup per
    page walks the longest shared prefix. ``seed`` partitions the key space
    (e.g. by LoRA adapter — the same tokens under a different adapter
    produce different K/V and must never alias)."""
    out: List[int] = []
    h = hash(("aqua-prefix", seed))
    for i in range(len(tokens) // page_tokens):
        h = hash((h, tuple(tokens[i * page_tokens:(i + 1) * page_tokens])))
        out.append(h)
    return out


class PagedStateRuntime:
    """Family-agnostic block-table state manager on tiered AquaTensor pools."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int,
                 page_tokens: int = 8, local_pages: Optional[int] = None,
                 host_pages: int = 8192, n_logical: int = 16384,
                 max_running: int = 4, meter: Optional[TransferMeter] = None,
                 prefix_sharing: bool = True, mesh=None):
        """Build one AquaTensor pool per page plane of ``cfg``'s family.

        Args:
            cfg: model config; must be paged-servable (``lm.supports_paged``).
            max_seq: maximum context length a request may reach; sizes the
                per-request block tables (``pps`` pages per layer).
            page_tokens: tokens per token-plane page.
            local_pages: LOCAL slots of each token plane (the admission
                budget the schedulers plan against); default sizes for
                ``max_running`` full-length requests.
            host_pages: host-tier slots per plane (the PCIe fallback).
            n_logical: logical page ids per plane.
            max_running: used only to size default pools.
            meter: shared ``TransferMeter``; a fresh one by default.
            prefix_sharing: enable the copy-on-write prefix index. Forced
                off when any plane is not ``shareable`` (recurrent state).
            mesh: optional ``MeshTierDomain`` — every plane's REMOTE pools
                become real peer-device slabs and remote transfer legs
                become collectives; None keeps the single-device backend.

        Raises:
            ValueError: the family has a sub-layer with no page plane
                (windowed ring buffers, logit softcap, encoder-decoder).
        """
        from repro.models import lm
        if not lm.supports_paged(cfg):
            raise ValueError(f"{cfg.name}: not paged-servable (windowed "
                             "ring-buffer / softcap / encdec layers have no "
                             "page plane yet)")
        self.cfg = cfg
        self.G = lm.n_groups(cfg)
        self.gs = lm.group_size(cfg)
        self.page_tokens = page_tokens
        self.max_seq = max_seq
        self.pps = math.ceil(max_seq / page_tokens)
        self.meter = meter or TransferMeter()
        self.mesh = mesh
        self.faults = None
        self.planes: Dict[str, _Plane] = {}
        layout = lm.paged_layout(cfg)
        # prefix sharing requires every plane to be position-addressed and
        # immutable once written (token planes); one recurrent state plane
        # disables it for the whole family — skipping a shared chunk would
        # skip its state recurrence
        self.sharing = bool(prefix_sharing) and all(
            spec.get("shareable", False) for spec in layout.values())
        # prefix index: chain hash -> {plane: (n_layers,) logical page ids,
        # "_prefix": the exact token prefix, "_seed": the hash seed}. The
        # stored prefix is compared verbatim on every match — a chain-hash
        # collision can never alias one prompt's KV into another's block
        # tables. Entries are backed by live requests' refcounts (no owner
        # of their own) and dropped the moment their backing pages are freed.
        self._index: Dict[int, Dict[str, object]] = {}
        self._lp_entry: Dict[Tuple[str, int], int] = {}
        self._req_hashes: Dict[int, List[int]] = {}
        self._req_tokens: Dict[int, Tuple[int, ...]] = {}
        self._req_seed: Dict[int, object] = {}
        self._req_registered: Dict[int, int] = {}
        self._active: set = set()
        self.prefix_hits = 0
        self.adopted_tokens = 0
        self.cow_copies = 0
        for name, spec in layout.items():
            n_sub = len(spec["positions"])
            n_layers = self.G * n_sub
            if spec["kind"] == "tokens":
                if name == "kv":
                    K, hd = spec["dims"]
                    page_shape: Tuple[int, ...] = (2, K, page_tokens, hd)
                else:                                   # mla latent plane
                    (C,) = spec["dims"]
                    page_shape = (page_tokens, C)
                per_req = n_layers * self.pps
                # token-plane LOCAL budget is caller-tunable (the admission
                # gate the schedulers plan against); +1 is the scratch page
                slots = (local_pages if local_pages is not None
                         else max_running * per_req + 1)
            else:
                page_shape = spec["shape"]
                per_req = n_layers
                slots = max_running * per_req + 1
            aqua = AquaTensor(n_logical=n_logical, page_shape=page_shape,
                              local_slots=slots, host_slots=host_pages,
                              dtype=spec["dtype"], meter=self.meter,
                              name=f"{cfg.name}/{name}", mesh=mesh)
            plane = _Plane(name, spec["kind"], aqua, n_layers, n_sub,
                           token_bytes=spec.get("token_bytes", 0))
            # pinned LOCAL dummy page: idle batch lanes and block-table
            # padding point here so masked DMAs / idle-lane state reads and
            # writes stay in-bounds
            plane.scratch_lp = int(aqua.allocate(1, prefer=LOCAL)[0])
            self.planes[name] = plane

    # -- geometry ---------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Token-plane pages per layer covering n_tokens."""
        return max(1, math.ceil(n_tokens / self.page_tokens))

    def _plane_pages(self, plane: _Plane, n_tokens: int) -> int:
        if plane.kind == "tokens":
            return plane.n_layers * self.pages_for(n_tokens)
        return plane.n_layers

    def pages_per_request(self, n_tokens: int) -> np.ndarray:
        """Per-plane page cost of a request at n_tokens of context — the
        vector the schedulers budget against (one entry per plane)."""
        return np.asarray([self._plane_pages(p, n_tokens)
                           for p in self.planes.values()], np.int64)

    def footprint_bytes(self, n_tokens: int) -> float:
        """Native-dtype whole-context bytes of a request (no page slack):
        token planes at n_tokens, recurrent state planes at their fixed
        size. This is exactly what one park/restore moves."""
        total = 0.0
        for p in self.planes.values():
            if p.kind == "tokens":
                total += p.n_layers * n_tokens * p.token_bytes
            else:
                total += p.n_layers * p.aqua.page_bytes
        return float(total)

    def footprint_elems(self, n_tokens: int) -> int:
        """Element count of the same footprint (the seed blob path moved
        4 bytes per element, whatever the native dtype)."""
        total = 0
        for p in self.planes.values():
            per_page = int(np.prod(p.aqua.page_shape))
            if p.kind == "tokens":
                total += p.n_layers * n_tokens * (p.token_bytes
                                                  // p.aqua.dtype.itemsize)
            else:
                total += p.n_layers * per_page
        return total

    @property
    def page_budget(self) -> np.ndarray:
        """Per-plane LOCAL pages available to requests (scratch excluded)."""
        return np.asarray([p.aqua.local_pool.shape[0] - 1
                           for p in self.planes.values()], np.int64)

    @property
    def aqua(self) -> AquaTensor:
        """The sole plane's tensor — attention-only (or ssm-state-only)
        convenience for tests/benchmarks; multi-plane runtimes must address
        ``planes[name].aqua`` explicitly."""
        if len(self.planes) != 1:
            raise AttributeError("runtime has multiple planes; use "
                                 f".planes[name].aqua ({list(self.planes)})")
        return next(iter(self.planes.values())).aqua

    # -- pool plumbing (the jit operands) ---------------------------------
    @property
    def pools(self) -> Dict[str, jnp.ndarray]:
        return {n: p.aqua.local_pool for n, p in self.planes.items()}

    @pools.setter
    def pools(self, value: Dict[str, jnp.ndarray]):
        for n, pool in value.items():
            self.planes[n].aqua.local_pool = pool

    # -- activation bookkeeping (LOCAL pins) -------------------------------
    def _unpin(self, plane: _Plane, lp: int):
        c = plane.pin.get(lp, 0) - 1
        if c <= 0:
            plane.pin.pop(lp, None)
        else:
            plane.pin[lp] = c

    def _activate(self, rid: int):
        """Mark the request active: pull every page it references LOCAL
        (adopted prefix pages may sit on another tier) and pin them there —
        a pinned page is never offloaded by another sharer's park. All
        planes' page-ins ride ONE coalesced message per (tier, donor)."""
        if rid in self._active:
            return
        self._active.add(rid)
        with self.meter.coalesce():
            for plane in self.planes.values():
                lps = plane.flat(rid)
                if len(lps):
                    plane.aqua.ensure_local(lps)
                    plane.aqua.set_page_fill(lps, 1.0)
                    for lp in lps:
                        lp = int(lp)
                        plane.pin[lp] = plane.pin.get(lp, 0) + 1

    # -- allocation -------------------------------------------------------
    def ensure_capacity(self, rid: int, n_tokens: int):
        """Grow the request's block tables to cover ``n_tokens`` of context.

        Token planes add pages as the context crosses page boundaries
        (adopted shared-prefix pages already in the tables count toward the
        need); state planes allocate their fixed page set on first touch
        (zeroed — a freed slot may hold a previous occupant's state, and the
        zero page IS the initial recurrent state). Implicitly activates the
        request: its existing pages are pulled LOCAL and pinned.

        New pages must be LOCAL (the step programs read the LOCAL pools): if
        the allocator had to spill a fresh page to another tier the LOCAL
        pool is full and no later step could pull it back either, so fail
        loudly here with the tensor/tier MemoryError. The page-budget-aware
        schedulers are designed to keep planned run sets below this point.

        The grow is ALL-OR-NOTHING across planes: if any plane's pool runs
        dry mid-way, every page this call already took — in this plane and
        the planes before it — is unpinned and released before the
        MemoryError propagates, so a failed hybrid (multi-plane) grow never
        leaks pages or refcounts.

        Raises:
            MemoryError: a fresh page cannot be placed (or kept) LOCAL.
        """
        self._activate(rid)
        added: List[Tuple[_Plane, List[int], int]] = []
        fresh_rids: List[_Plane] = []     # planes whose rows this call made
        try:
            for plane in self.planes.values():
                if rid not in plane.pages:
                    fresh_rids.append(plane)
                rows = plane.pages.setdefault(
                    rid, [[] for _ in range(plane.n_layers)])
                need = (self.pages_for(n_tokens) if plane.kind == "tokens"
                        else 1)
                fresh: List[int] = []
                for row in rows:
                    while len(row) < need:
                        lp = int(plane.aqua.allocate(1, prefer=LOCAL)[0])
                        try:
                            if plane.aqua.page_table[lp, 0] != LOCAL:
                                plane.aqua.ensure_local([lp])  # LOCAL full
                        except MemoryError:
                            plane.aqua.free([lp])   # spilled page: unwind it
                            raise
                        row.append(lp)
                        added.append((plane, row, lp))
                        plane.pin[lp] = plane.pin.get(lp, 0) + 1
                        if plane.kind == "state":
                            fresh.append(lp)
                if fresh:
                    plane.aqua.write_local(
                        fresh,
                        jnp.zeros((len(fresh),) + plane.aqua.page_shape,
                                  plane.aqua.dtype))
        except MemoryError:
            for plane, row, lp in reversed(added):
                self._unpin(plane, lp)
                plane.aqua.free([lp])
                row.remove(lp)
            for plane in fresh_rids:
                if not any(plane.pages.get(rid, [])):
                    plane.pages.pop(rid, None)
            raise

    def release(self, rid: int):
        """Drop the request's references: pages it shares with a live
        request survive (the sharer keeps reading them — they are never
        zeroed or reused while referenced); exclusively-owned pages are
        freed, and any prefix-index entries they backed are dropped so a
        recycled logical id can never serve a stale prefix match."""
        for plane in self.planes.values():
            if rid not in plane.pages:
                continue
            lps = plane.flat(rid)
            if rid in self._active:
                for lp in lps:
                    self._unpin(plane, int(lp))
            for lp in plane.aqua.free(lps):
                self._drop_index_entry(plane.name, lp)
            del plane.pages[rid]
        self._active.discard(rid)
        self._req_hashes.pop(rid, None)
        self._req_tokens.pop(rid, None)
        self._req_seed.pop(rid, None)
        self._req_registered.pop(rid, None)

    def _drop_index_entry(self, plane_name: str, lp: int):
        h = self._lp_entry.pop((plane_name, int(lp)), None)
        if h is None:
            return
        entry = self._index.pop(h, None)
        if entry:
            for name, lps in entry.items():
                if name.startswith("_"):
                    continue
                for e in lps:
                    self._lp_entry.pop((name, int(e)), None)

    # -- prefix sharing (refcounted copy-on-write pages) -------------------
    def adopt_prefix(self, rid: int, tokens: Sequence[int],
                     seed: object = None) -> int:
        """Map a new request's block tables onto already-resident pages for
        the longest indexed page-aligned prefix of ``tokens``.

        For every matched page the physical page is RETAINED (refcount + 1)
        and its logical id appended to this request's block-table rows in
        every plane — the chunked-prefill pipeline then starts past the
        shared prefix (the engine sets ``prefill_pos`` accordingly). Must be
        called before the request's first ``ensure_capacity``. Also caches
        the prompt's block-hash chain so the request's own full pages can be
        registered as it prefills (``register_prefix``).

        Args:
            rid: the request id (no pages allocated yet).
            tokens: the full prompt token ids.
            seed: extra hash seed partitioning the index (e.g. lora_id).

        Returns:
            Matched prefix length in TOKENS (a multiple of ``page_tokens``;
            0 when sharing is disabled or nothing matches). The caller must
            still compute at least the final prompt position for logits —
            on a full match that recompute write triggers copy-on-write of
            the tail page (``make_writable``).
        """
        if not self.sharing:
            return 0
        hashes = _hash_blocks(tokens, self.page_tokens, seed)
        self._req_hashes[rid] = hashes
        self._req_tokens[rid] = tuple(map(int, tokens))
        self._req_seed[rid] = seed
        n = 0
        for p, h in enumerate(hashes):
            entry = self._index.get(h)
            if (entry is None or entry["_seed"] != seed
                    or entry["_prefix"] != self._req_tokens[rid]
                    [:(p + 1) * self.page_tokens]):
                break                   # miss (or a chain-hash collision)
            n += 1
        self._req_registered[rid] = n
        if n == 0:
            return 0
        if any(rid in p.pages for p in self.planes.values()):
            raise ValueError(f"adopt_prefix({rid}) after pages were "
                             "allocated — adoption must precede the first "
                             "ensure_capacity")
        for name, plane in self.planes.items():
            rows = plane.pages.setdefault(
                rid, [[] for _ in range(plane.n_layers)])
            for p in range(n):
                lps = self._index[hashes[p]][name]
                plane.aqua.retain(lps)
                for l in range(plane.n_layers):
                    rows[l].append(int(lps[l]))
        self.prefix_hits += 1
        self.adopted_tokens += n * self.page_tokens
        return n * self.page_tokens

    def register_prefix(self, rid: int, n_tokens: int):
        """Publish the request's completed full prompt pages into the prefix
        index (up to ``n_tokens`` positions written so far). Pages adopted
        from the index are already there; decode-written pages are never
        registered (the hash chain covers prompt blocks only). No-op unless
        ``adopt_prefix`` cached the request's hash chain."""
        hashes = self._req_hashes.get(rid)
        if not self.sharing or hashes is None:
            return
        n_full = min(n_tokens // self.page_tokens, len(hashes))
        start = self._req_registered.get(rid, 0)
        for p in range(start, n_full):
            h = hashes[p]
            if h in self._index:        # a concurrent twin won the race
                continue
            entry: Dict[str, object] = {
                "_prefix": self._req_tokens[rid][:(p + 1) * self.page_tokens],
                "_seed": self._req_seed.get(rid),
            }
            for name, plane in self.planes.items():
                rows = plane.pages.get(rid)
                if rows is None or len(rows[0]) <= p:
                    return
                entry[name] = np.asarray(
                    [rows[l][p] for l in range(plane.n_layers)], np.int64)
            self._index[h] = entry
            for name, lps in entry.items():
                if name.startswith("_"):
                    continue
                for lp in lps:
                    self._lp_entry[(name, int(lp))] = h
        self._req_registered[rid] = max(start, n_full)

    def make_writable(self, rid: int, start: int, end: int):
        """Copy-on-write: before the request writes token positions
        ``[start, end)``, clone any covered page it SHARES (refcount > 1)
        into a fresh exclusive LOCAL page and repoint only this request's
        block-table row at the clone. The other referencers (and the prefix
        index) keep the original — a sharer's write can never corrupt the
        prefix another request is still reading.

        Raises:
            MemoryError: no LOCAL slot is free for a clone.
        """
        if not self.sharing or end <= start:
            return
        p0, p1 = start // self.page_tokens, (end - 1) // self.page_tokens
        for plane in self.planes.values():
            if plane.kind != "tokens":
                continue
            rows = plane.pages.get(rid)
            if not rows:
                continue
            for row in rows:
                for p in range(p0, min(p1 + 1, len(row))):
                    lp = int(row[p])
                    if int(plane.aqua.refcounts([lp])[0]) <= 1:
                        continue
                    new = int(plane.aqua.allocate(1, prefer=LOCAL)[0])
                    try:
                        if plane.aqua.page_table[new, 0] != LOCAL:
                            plane.aqua.ensure_local([new])
                    except MemoryError:
                        # the clone spilled and cannot be pulled back: hand
                        # it straight back instead of leaking it (the block
                        # table still points at the shared original)
                        plane.aqua.free([new])
                        raise
                    plane.aqua.write_local([new], plane.aqua.read([lp]))
                    if rid in self._active:
                        self._unpin(plane, lp)
                        plane.pin[new] = plane.pin.get(new, 0) + 1
                    plane.aqua.free([lp])      # deref; sharers keep it
                    row[p] = new
                    self.cow_copies += 1

    def shared_pages_with(self, rid: int, other_rids: Sequence[int]
                          ) -> np.ndarray:
        """Per-plane count of this request's pages also referenced by any of
        ``other_rids`` — the physical-page discount the schedulers apply
        when budgeting a run set that contains both sharers."""
        out = []
        for plane in self.planes.values():
            mine = plane.pages.get(rid)
            if not mine:
                out.append(0)
                continue
            mine_set = {lp for row in mine for lp in row}
            shared = set()
            for o in other_rids:
                for row in plane.pages.get(o, []):
                    shared.update(mine_set.intersection(row))
            out.append(len(shared))
        return np.asarray(out, np.int64)

    def cow_reserve(self) -> np.ndarray:
        """Per-plane pages a pending copy-on-write may allocate (one clone
        per layer row of each token plane): the scheduler headroom for a
        fully-matched request that must still recompute its final prompt
        position."""
        return np.asarray([p.n_layers if p.kind == "tokens" else 0
                           for p in self.planes.values()], np.int64)

    def physical_pages(self) -> Dict[str, int]:
        """Allocated PHYSICAL pages per plane (a page shared by N block
        tables counts once) — what eviction and MemoryError accounting see."""
        return {n: int((p.aqua.page_table[:, 0] != -1).sum())
                for n, p in self.planes.items()}

    def logical_pages(self) -> Dict[str, int]:
        """Block-table page references per plane (a page shared by N block
        tables counts N times) — the unshared footprint for comparison."""
        return {n: sum(len(row) for rows in p.pages.values() for row in rows)
                for n, p in self.planes.items()}

    # -- block tables (the step-program operands) --------------------------
    def block_tables_prefill(self, rid: int, pad_to: Optional[int] = None
                             ) -> Dict[str, jnp.ndarray]:
        """One request's tables from position 0: token planes as
        (G, n_sub, pad_to) physical LOCAL slots, scratch-padded; state
        planes as (G, n_sub) bare slots. Chunked prefill passes a FIXED
        ``pad_to`` (pps plus the write-window spill) so every chunk of every
        request shares one table shape — no retrace per context length."""
        out = {}
        for name, plane in self.planes.items():
            rows = plane.pages[rid]
            if plane.kind == "tokens":
                bt = plane.aqua.block_tables(rows,
                                             pad_to=pad_to or len(rows[0]),
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub, -1))
            else:
                bt = plane.aqua.block_tables(rows, pad_to=1,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub))
        return out

    def block_tables(self, lane_rids: Sequence[Optional[int]],
                     pad_to: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        """Batched row query (decode lanes, or the fused step's packed
        decode+chunk rows): token planes as (G, n_sub, B, pad_to) physical
        LOCAL slots (``pad_to`` defaults to ``pps``; the fused step passes
        ``pps`` plus the chunk write-window spill so every row shares one
        shape), state planes as (G, n_sub, B); empty lanes and padding
        point at each plane's scratch page."""
        B = len(lane_rids)
        tok_pad = pad_to or self.pps
        out = {}
        for name, plane in self.planes.items():
            rows: List[List[int]] = []
            for l in range(plane.n_layers):
                for rid in lane_rids:
                    rows.append(plane.pages[rid][l] if rid is not None else [])
            if plane.kind == "tokens":
                bt = plane.aqua.block_tables(rows, pad_to=tok_pad,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(
                    bt.reshape(self.G, plane.n_sub, B, tok_pad))
            else:
                bt = plane.aqua.block_tables(rows, pad_to=1,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub, B))
        return out

    # -- tier migration (preempt / restore as page-table flips) ------------
    def park(self, rid: int, n_tokens: int, *, prefer: int = REMOTE):
        """Preempt: flip the request's pages out of LOCAL — ALL planes fused
        into one coalesced message per (tier, donor) group (a hybrid's kv +
        ssm + conv pages ride one staging buffer, not one message per
        plane), token pages metered at their fill, state pages whole (they
        are always fully live).

        ``n_tokens`` is the context actually RESIDENT in the pools (for an
        engine request at ctx_len that is ctx_len-1: the newest token's
        state lands at its next decode step). A token page allocated ahead
        of a boundary but not yet written moves at fill 0.

        Shared pages move ONCE: parking drops this request's LOCAL pin, and
        only pages whose pin count reaches zero (no other active sharer) are
        offloaded — a shared prefix page leaves LOCAL when its LAST active
        referencer parks, and is metered full (its payload is complete
        whatever this request's own resident prefix is).
        """
        with self.meter.coalesce():
            for plane in self.planes.values():
                if rid not in plane.pages:
                    continue
                if plane.kind == "tokens":
                    for row in plane.pages[rid]:
                        fills = np.clip(
                            n_tokens - np.arange(len(row)) * self.page_tokens,
                            0, self.page_tokens) / self.page_tokens
                        # shared prefix pages are always fully written (only
                        # full prompt pages enter the index)
                        fills = np.where(plane.aqua.refcounts(row) > 1,
                                         1.0, fills)
                        plane.aqua.set_page_fill(row, fills)
                lps = plane.flat(rid)
                if rid in self._active:
                    for lp in lps:
                        self._unpin(plane, int(lp))
                victims = [int(lp) for lp in lps
                           if plane.pin.get(int(lp), 0) == 0]
                if victims:
                    plane.aqua.offload(np.asarray(victims, np.int64),
                                       prefer=prefer)
        self._active.discard(rid)

    def restore(self, rid: int):
        """Make every page of the request LOCAL and pin it there (no bytes
        move for pages a still-active sharer kept LOCAL); resets token-page
        fills to 1.0. No-op when the request is already active."""
        self._activate(rid)

    def nonlocal_pages(self, rid: int) -> np.ndarray:
        """Per-plane pages of the request currently NOT in the LOCAL tier."""
        out = []
        for plane in self.planes.values():
            rows = plane.aqua.page_table[plane.flat(rid)]
            out.append(int((rows[:, 0] != LOCAL).sum()) if len(rows) else 0)
        return np.asarray(out, np.int64)

    def can_restore(self, rid: int) -> bool:
        """True when a restore fits every plane's free LOCAL slots right now
        — the prefetch guard: an early ``ensure_local`` must never steal
        pages the current run set still needs (it would raise mid-step)."""
        free = np.asarray([p.aqua.local_free for p in self.planes.values()])
        return bool(np.all(self.nonlocal_pages(rid) <= free))

    # -- coordinator-driven lease plumbing --------------------------------
    def add_remote_lease(self, donor: str, nbytes: float):
        """Split a donor's byte grant across the planes in proportion to
        their share of a full-length request's footprint. Slots are floored
        per plane so the booked capacity never exceeds the grant the
        coordinator accounts (a plane whose share rounds to zero simply
        gets no pool from this donor and falls through to the host tier);
        a grant too small for any plane's page goes whole to the
        largest-weight plane, matching the old single-pool ``max(1, ...)``."""
        weights = {n: float(self._plane_pages(p, self.max_seq)
                            * p.aqua.page_bytes)
                   for n, p in self.planes.items()}
        total = sum(weights.values())
        slots = {n: int(nbytes * weights[n] / total // p.aqua.page_bytes)
                 for n, p in self.planes.items()}
        if not any(slots.values()):
            slots[max(weights, key=weights.get)] = 1
        for name, n_slots in slots.items():
            if n_slots > 0:
                self.planes[name].aqua.add_remote_lease(donor, n_slots)

    def evict_remote(self, donor: str) -> int:
        """Honor a donor reclaim: evacuate every PHYSICAL page parked on the
        donor's pools to the host tier and drop the lease (the paper's
        iteration-boundary ``aqua.respond()``). A page shared by several
        block tables moves once. Returns pages moved.

        Raises:
            MemoryError: the host tier cannot absorb the evacuation.
        """
        with self.meter.coalesce():
            return sum(p.aqua.evict_remote(donor)
                       for p in self.planes.values()
                       if donor in p.aqua.remote_pools)

    # -- fault plumbing (lease revocation, donor loss) ---------------------
    def attach_faults(self, faults) -> None:
        """Share one ``core/faults.FaultInjector`` with every plane's tensor
        (transfer-leg retry consults) and the mesh domain (lease-boundary
        guards on the collective legs)."""
        self.faults = faults
        for plane in self.planes.values():
            plane.aqua.faults = faults
        if self.mesh is not None:
            self.mesh.attach_faults(faults)

    def shrink_lease(self, donor: str, frac: float) -> int:
        """Dynamic donor-side memory pressure: the donor reclaims ``frac``
        of its leased slots in EVERY plane, NOW (unlike ``evict_remote``
        this is partial, and unlike the coordinator reclaim poll it is not
        deferred to a respond boundary — the donor's own traffic needs the
        HBM). Occupied reclaimed slots live-migrate to the remaining donors
        or the host tier, all planes fused into one coalesced message per
        (tier, donor) group. Returns pages migrated.

        Raises:
            LeaseRevokedError: no live lease from this donor in any plane.
            MemoryError: the surviving tiers cannot absorb the migration.
        """
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"shrink fraction {frac} not in (0, 1]")
        holders = [p for p in self.planes.values()
                   if donor in p.aqua.remote_pools]
        if not holders:
            raise LeaseRevokedError(
                f"shrink of donor {donor} without a live lease in any plane",
                donor=donor)
        moved = 0
        with self.meter.coalesce():
            for plane in holders:
                n = math.ceil(frac * plane.aqua.remote_capacity[donor])
                moved += plane.aqua.shrink_lease(donor, n)
        return moved

    def fail_donor(self, donor: str) -> List[int]:
        """Permanent donor loss: every page resident on the donor (every
        plane) flips to the LOST tier and the leases drop. Returns the
        sorted rids of VICTIM requests — those whose block tables reference
        a lost page — for the engine's recompute-from-prompt recovery.
        Prefix-index entries backed by lost pages are dropped immediately,
        so no later arrival can adopt a dead prefix."""
        victims: set = set()
        for plane in self.planes.values():
            if donor not in plane.aqua.remote_pools:
                continue
            lost = set(int(l) for l in plane.aqua.fail_donor(donor))
            if not lost:
                continue
            for lp in lost:
                self._drop_index_entry(plane.name, lp)
            for rid, rows in plane.pages.items():
                if any(int(lp) in lost for row in rows for lp in row):
                    victims.add(rid)
        if self.faults is not None:
            self.faults.mark_donor_lost(donor)
        return sorted(victims)

    def total_capacity(self) -> np.ndarray:
        """Per-plane PHYSICAL slots across every live tier (scratch
        excluded): what the runtime can hold AT ALL, LOCAL or parked. The
        engine re-plans the scheduler budget against this after a lease
        shrinks or a donor dies — admission must contract when the tiers
        backing preemption vanish."""
        return np.asarray(
            [p.aqua.local_pool.shape[0] - 1 + p.aqua.host_pool.shape[0]
             + sum(p.aqua.remote_capacity.values())
             for p in self.planes.values()], np.int64)

    def stats(self) -> Dict:
        """Tier occupancy per plane, transfer-meter totals, and the prefix-
        sharing counters (hits, adopted tokens, copy-on-write clones,
        physical vs logical page counts)."""
        tiers: Dict[str, int] = {}
        for p in self.planes.values():
            for k, v in p.aqua.tier_counts().items():
                tiers[k] = tiers.get(k, 0) + v
        return {"tiers": tiers,
                "planes": {n: p.aqua.tier_counts()
                           for n, p in self.planes.items()},
                "page_tokens": self.page_tokens,
                "sharing": {"enabled": self.sharing,
                            "prefix_hits": self.prefix_hits,
                            "adopted_tokens": self.adopted_tokens,
                            "cow_copies": self.cow_copies,
                            "physical_pages": self.physical_pages(),
                            "logical_pages": self.logical_pages()},
                "meter": {"bytes_fabric": self.meter.bytes_fabric,
                          "bytes_host": self.meter.bytes_host,
                          "messages_fabric": self.meter.messages_fabric,
                          "messages_host": self.meter.messages_host,
                          "retries_fabric": self.meter.retries_fabric,
                          "retries_host": self.meter.retries_host,
                          "sim_time": self.meter.sim_time}}
