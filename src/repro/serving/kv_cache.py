"""Unified paged state runtime: EVERY family's dynamic context on AquaTensor
pages, behind per-request block tables.

``PagedStateRuntime`` is the serving engine's state manager (paper §3 + §5
made structural, for the paper's whole model zoo): each family's per-request
dynamic context is decomposed by ``models/lm.py:paged_layout`` into page
PLANES — one tiered AquaTensor pool per plane, native-dtype payloads:

    kv     (2, n_kv, page, hd)   attention K/V, ceil(ctx/page) pages/layer
    mla    (page, kv_lora+rope)  fused MLA latent + roped key, token-paged
    ssm    (d_inner, d_state)    Mamba SSM state (f32), one page/layer
    conv   (d_conv-1, d_inner)   Mamba conv tail, one page/layer
    wkv    (H, hd, hd)           RWKV6 wkv state (f32), one page/layer
    shift  (2, d_model)          RWKV6 time/channel-mix shifts, one page/layer

A hybrid (Jamba) request owns kv pages for its attention sub-layers and
ssm/conv pages for the Mamba ones; an RWKV6 request owns only fixed-size
state pages (O(1) context). Decode/prefill read and write the LOCAL pools
directly inside the jit'd whole-step programs (attention through the
``kernels/paged_attention`` block-table kernels, MLA/recurrent planes via
shape-stable jnp gathers/scatters), so preemption is a *page-table tier
flip* for every family:

    park    = offload(pages)      one coalesced message per (tier, donor)
    restore = ensure_local(pages) group across ALL planes of the request

— no gather of cache leaves, no float32 blob, no repacking, for ANY family.
Partial token-plane tails are metered at their valid fraction, so a parked
request moves exactly its native-dtype context footprint. The seed-era dense
blob-store shim this replaces is deleted; there is exactly one way a
request's state moves between tiers.

PREFIX SHARING (copy-on-write): the same by-reference insight applies
*within* the resident tier. A RADIX TREE over page-aligned prompt token
blocks lets ``adopt_prefix`` map a new request's block tables onto the
physical pages another request already wrote for the longest common prefix
of its prompt — mid-prompt divergence splits a tree edge at the block
boundary, so two prompts sharing 40 of 60 blocks share 40 physical pages.
Children are keyed by their first token block verbatim (a dict lookup is a
hash PLUS an exact tuple compare), so a hash collision is a miss, never
foreign pages. Shared pages are refcounted in the AquaTensor
(``page_refs``), pinned LOCAL while any referencer is active, moved between
tiers ONCE however many block tables point at them, and copied on write
(``make_writable``) the moment a sharer must write into one. Sharing is
enabled only when every plane is ``shareable`` (token planes:
position-addressed, immutable once written); families with recurrent state
planes opt out — a state page summarizes the whole prefix and is rewritten
every step.

GLOBAL PREFIX CACHE (retain past refcount 0): with ``prefix_cache`` on,
tree-indexed pages OUTLIVE their last referencer in a CACHED state
(refcount 0, physical slot kept, payload intact, any tier) so the next
request with the same prompt prefix revives them instead of recomputing
prefill. Cached pages count against the same pools as live pages but YIELD
on demand: every plane's AquaTensor carries a ``reclaim`` hook that evicts
the coldest cached leaf blocks (LRU) with cold-first demotion
LOCAL -> REMOTE -> HOST -> free before any tier-exhausted MemoryError can
fire — a cache-on run never fails an allocation a cache-off run would have
served. Donor death drops (never leaks) cached pages on the dead slab and
prunes their radix coverage.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import (AquaTensor, HOST, LOCAL, REMOTE,
                                    TransferMeter)
from repro.core.errors import LeaseRevokedError


@dataclass
class _Plane:
    """One page plane: an AquaTensor pool + the per-request page bookkeeping."""
    name: str
    kind: str                        # "tokens" | "state"
    aqua: AquaTensor
    n_layers: int                    # plane layers across the whole stack
    n_sub: int                       # plane sub-layers per group
    token_bytes: int = 0             # per-layer bytes/token (token planes)
    scratch_lp: int = 0
    pages: Dict[int, List[List[int]]] = field(default_factory=dict)
    # LOCAL pin count per logical page: how many ACTIVE (unparked)
    # requests reference it. park() may only offload pages whose pin
    # reaches zero — a shared prefix page stays LOCAL while any sharer
    # still runs, and moves tiers exactly once when the last sharer parks.
    pin: Dict[int, int] = field(default_factory=dict)

    @property
    def scratch_slot(self) -> int:
        return int(self.aqua.page_table[self.scratch_lp, 1])

    def flat(self, rid: int) -> np.ndarray:
        return np.asarray([lp for row in self.pages.get(rid, [])
                           for lp in row], np.int64)


def _token_blocks(tokens: Sequence[int], page_tokens: int
                  ) -> List[Tuple[int, ...]]:
    """A prompt's FULL page-aligned token blocks (the partial tail block is
    never indexed — only completely written pages are shareable)."""
    return [tuple(int(t) for t in tokens[i * page_tokens:(i + 1) * page_tokens])
            for i in range(len(tokens) // page_tokens)]


class _RadixNode:
    """One edge of the prefix radix tree: a run of page-aligned token blocks
    plus the physical pages backing each block.

    ``blocks[i]`` is the i-th token block of the edge verbatim and
    ``pages[i]`` maps plane name -> (n_layers,) logical page ids holding its
    context. Children are keyed by their OWN first block, so descending is a
    dict lookup whose tuple-equality compare IS the exact-token
    verification: a hash collision falls through ``==`` and reads as a miss,
    never as foreign pages. ``last_use`` is the runtime's LRU clock tick of
    the newest adoption/registration through this node — eviction takes the
    coldest cached leaf block first. One root per index seed (lora_id):
    adapters never alias even for identical token streams."""
    __slots__ = ("blocks", "pages", "children", "parent", "last_use")

    def __init__(self, blocks: Optional[List[Tuple[int, ...]]] = None,
                 pages: Optional[List[Dict[str, np.ndarray]]] = None,
                 parent: Optional["_RadixNode"] = None):
        self.blocks: List[Tuple[int, ...]] = blocks if blocks is not None else []
        self.pages: List[Dict[str, np.ndarray]] = pages if pages is not None else []
        self.children: Dict[Tuple[int, ...], "_RadixNode"] = {}
        self.parent: Optional["_RadixNode"] = parent
        self.last_use: int = 0


class PagedStateRuntime:
    """Family-agnostic block-table state manager on tiered AquaTensor pools."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int,
                 page_tokens: int = 8, local_pages: Optional[int] = None,
                 host_pages: int = 8192, n_logical: int = 16384,
                 max_running: int = 4, meter: Optional[TransferMeter] = None,
                 prefix_sharing: bool = True, prefix_cache: bool = True,
                 mesh=None):
        """Build one AquaTensor pool per page plane of ``cfg``'s family.

        Args:
            cfg: model config; must be paged-servable (``lm.supports_paged``).
            max_seq: maximum context length a request may reach; sizes the
                per-request block tables (``pps`` pages per layer).
            page_tokens: tokens per token-plane page.
            local_pages: LOCAL slots of each token plane (the admission
                budget the schedulers plan against); default sizes for
                ``max_running`` full-length requests.
            host_pages: host-tier slots per plane (the PCIe fallback).
            n_logical: logical page ids per plane.
            max_running: used only to size default pools.
            meter: shared ``TransferMeter``; a fresh one by default.
            prefix_sharing: enable the copy-on-write prefix index. Forced
                off when any plane is not ``shareable`` (recurrent state).
            prefix_cache: retain tree-indexed pages past refcount 0 in the
                CACHED state (global prefix cache) instead of freeing them
                with their last referencer. Effective only with sharing on.
            mesh: optional ``MeshTierDomain`` — every plane's REMOTE pools
                become real peer-device slabs and remote transfer legs
                become collectives; None keeps the single-device backend.

        Raises:
            ValueError: the family has a sub-layer with no page plane
                (windowed ring buffers, logit softcap, encoder-decoder).
        """
        from repro.models import lm
        if not lm.supports_paged(cfg):
            raise ValueError(f"{cfg.name}: not paged-servable (windowed "
                             "ring-buffer / softcap / encdec layers have no "
                             "page plane yet)")
        self.cfg = cfg
        self.G = lm.n_groups(cfg)
        self.gs = lm.group_size(cfg)
        self.page_tokens = page_tokens
        self.max_seq = max_seq
        self.pps = math.ceil(max_seq / page_tokens)
        self.meter = meter or TransferMeter()
        self.mesh = mesh
        self.faults = None
        self.planes: Dict[str, _Plane] = {}
        layout = lm.paged_layout(cfg)
        # prefix sharing requires every plane to be position-addressed and
        # immutable once written (token planes); one recurrent state plane
        # disables it for the whole family — skipping a shared chunk would
        # skip its state recurrence
        self.sharing = bool(prefix_sharing) and all(
            spec.get("shareable", False) for spec in layout.values())
        # the prefix cache retains tree-indexed pages past refcount 0; it
        # only makes sense on top of the sharing index
        self.caching = self.sharing and bool(prefix_cache)
        # prefix RADIX TREE: one root per index seed (lora_id partitions the
        # key space — identical tokens under different adapters never
        # alias). Each node edge is a run of page-aligned token blocks with
        # the physical pages backing them; ``_lp_node`` is the reverse map
        # (plane, logical page) -> (node, block index) so release/eviction/
        # donor loss find a page's coverage in O(1). With caching ON the
        # tree OWNS refcount-0 pages (CACHED state); with caching OFF nodes
        # are backed purely by live requests' refcounts and pruned the
        # moment a backing page is freed.
        self._roots: Dict[object, _RadixNode] = {}
        self._lp_node: Dict[Tuple[str, int], Tuple[_RadixNode, int]] = {}
        self._req_blocks: Dict[int, List[Tuple[int, ...]]] = {}
        self._req_tokens: Dict[int, Tuple[int, ...]] = {}
        self._req_seed: Dict[int, object] = {}
        self._req_registered: Dict[int, int] = {}
        self._active: set = set()
        self.prefix_hits = 0
        self.adopted_tokens = 0
        self.cow_copies = 0
        # cache counters: a HIT is an adoption that revived at least one
        # refcount-0 block (pure sharing with a live sharer is not a cache
        # hit); evictions/demotions count whole blocks
        self.cache_hits = 0
        self.cache_hit_tokens = 0
        self.cache_evictions = 0
        self.cache_demotions = 0
        self._clock = 0
        self._evicting = False
        for name, spec in layout.items():
            n_sub = len(spec["positions"])
            n_layers = self.G * n_sub
            if spec["kind"] == "tokens":
                if name == "kv":
                    K, hd = spec["dims"]
                    page_shape: Tuple[int, ...] = (2, K, page_tokens, hd)
                else:                                   # mla latent plane
                    (C,) = spec["dims"]
                    page_shape = (page_tokens, C)
                per_req = n_layers * self.pps
                # token-plane LOCAL budget is caller-tunable (the admission
                # gate the schedulers plan against); +1 is the scratch page
                slots = (local_pages if local_pages is not None
                         else max_running * per_req + 1)
            else:
                page_shape = spec["shape"]
                per_req = n_layers
                slots = max_running * per_req + 1
            aqua = AquaTensor(n_logical=n_logical, page_shape=page_shape,
                              local_slots=slots, host_slots=host_pages,
                              dtype=spec["dtype"], meter=self.meter,
                              name=f"{cfg.name}/{name}", mesh=mesh)
            plane = _Plane(name, spec["kind"], aqua, n_layers, n_sub,
                           token_bytes=spec.get("token_bytes", 0))
            # pinned LOCAL dummy page: idle batch lanes and block-table
            # padding point here so masked DMAs / idle-lane state reads and
            # writes stay in-bounds
            plane.scratch_lp = int(aqua.allocate(1, prefer=LOCAL)[0])
            self.planes[name] = plane
            if self.caching:
                # cached pages yield before any allocation in this plane can
                # fail: the tensor consults this hook when a tier runs dry
                aqua.reclaim = (lambda tier, need, _n=name:
                                self._cache_reclaim(_n, tier, need))

    # -- geometry ---------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Token-plane pages per layer covering n_tokens."""
        return max(1, math.ceil(n_tokens / self.page_tokens))

    def _plane_pages(self, plane: _Plane, n_tokens: int) -> int:
        if plane.kind == "tokens":
            return plane.n_layers * self.pages_for(n_tokens)
        return plane.n_layers

    def pages_per_request(self, n_tokens: int) -> np.ndarray:
        """Per-plane page cost of a request at n_tokens of context — the
        vector the schedulers budget against (one entry per plane)."""
        return np.asarray([self._plane_pages(p, n_tokens)
                           for p in self.planes.values()], np.int64)

    def footprint_bytes(self, n_tokens: int) -> float:
        """Native-dtype whole-context bytes of a request (no page slack):
        token planes at n_tokens, recurrent state planes at their fixed
        size. This is exactly what one park/restore moves."""
        total = 0.0
        for p in self.planes.values():
            if p.kind == "tokens":
                total += p.n_layers * n_tokens * p.token_bytes
            else:
                total += p.n_layers * p.aqua.page_bytes
        return float(total)

    def footprint_elems(self, n_tokens: int) -> int:
        """Element count of the same footprint (the seed blob path moved
        4 bytes per element, whatever the native dtype)."""
        total = 0
        for p in self.planes.values():
            per_page = int(np.prod(p.aqua.page_shape))
            if p.kind == "tokens":
                total += p.n_layers * n_tokens * (p.token_bytes
                                                  // p.aqua.dtype.itemsize)
            else:
                total += p.n_layers * per_page
        return total

    @property
    def page_budget(self) -> np.ndarray:
        """Per-plane LOCAL pages available to requests (scratch excluded)."""
        return np.asarray([p.aqua.local_pool.shape[0] - 1
                           for p in self.planes.values()], np.int64)

    @property
    def aqua(self) -> AquaTensor:
        """The sole plane's tensor — attention-only (or ssm-state-only)
        convenience for tests/benchmarks; multi-plane runtimes must address
        ``planes[name].aqua`` explicitly."""
        if len(self.planes) != 1:
            raise AttributeError("runtime has multiple planes; use "
                                 f".planes[name].aqua ({list(self.planes)})")
        return next(iter(self.planes.values())).aqua

    # -- pool plumbing (the jit operands) ---------------------------------
    @property
    def pools(self) -> Dict[str, jnp.ndarray]:
        return {n: p.aqua.local_pool for n, p in self.planes.items()}

    @pools.setter
    def pools(self, value: Dict[str, jnp.ndarray]):
        for n, pool in value.items():
            self.planes[n].aqua.local_pool = pool

    # -- activation bookkeeping (LOCAL pins) -------------------------------
    def _unpin(self, plane: _Plane, lp: int):
        c = plane.pin.get(lp, 0) - 1
        if c <= 0:
            plane.pin.pop(lp, None)
        else:
            plane.pin[lp] = c

    def _activate(self, rid: int):
        """Mark the request active: pull every page it references LOCAL
        (adopted prefix pages may sit on another tier) and pin them there —
        a pinned page is never offloaded by another sharer's park. All
        planes' page-ins ride ONE coalesced message per (tier, donor)."""
        if rid in self._active:
            return
        self._active.add(rid)
        with self.meter.coalesce():
            for plane in self.planes.values():
                lps = plane.flat(rid)
                if len(lps):
                    plane.aqua.ensure_local(lps)
                    plane.aqua.set_page_fill(lps, 1.0)
                    for lp in lps:
                        lp = int(lp)
                        plane.pin[lp] = plane.pin.get(lp, 0) + 1

    # -- allocation -------------------------------------------------------
    def ensure_capacity(self, rid: int, n_tokens: int):
        """Grow the request's block tables to cover ``n_tokens`` of context.

        Token planes add pages as the context crosses page boundaries
        (adopted shared-prefix pages already in the tables count toward the
        need); state planes allocate their fixed page set on first touch
        (zeroed — a freed slot may hold a previous occupant's state, and the
        zero page IS the initial recurrent state). Implicitly activates the
        request: its existing pages are pulled LOCAL and pinned.

        New pages must be LOCAL (the step programs read the LOCAL pools): if
        the allocator had to spill a fresh page to another tier the LOCAL
        pool is full and no later step could pull it back either, so fail
        loudly here with the tensor/tier MemoryError. The page-budget-aware
        schedulers are designed to keep planned run sets below this point.

        The grow is ALL-OR-NOTHING across planes: if any plane's pool runs
        dry mid-way, every page this call already took — in this plane and
        the planes before it — is unpinned and released before the
        MemoryError propagates, so a failed hybrid (multi-plane) grow never
        leaks pages or refcounts.

        Raises:
            MemoryError: a fresh page cannot be placed (or kept) LOCAL.
        """
        self._activate(rid)
        added: List[Tuple[_Plane, List[int], int]] = []
        fresh_rids: List[_Plane] = []     # planes whose rows this call made
        try:
            for plane in self.planes.values():
                if rid not in plane.pages:
                    fresh_rids.append(plane)
                rows = plane.pages.setdefault(
                    rid, [[] for _ in range(plane.n_layers)])
                need = (self.pages_for(n_tokens) if plane.kind == "tokens"
                        else 1)
                fresh: List[int] = []
                for row in rows:
                    while len(row) < need:
                        lp = int(plane.aqua.allocate(1, prefer=LOCAL)[0])
                        try:
                            if plane.aqua.page_table[lp, 0] != LOCAL:
                                plane.aqua.ensure_local([lp])  # LOCAL full
                        except MemoryError:
                            plane.aqua.free([lp])   # spilled page: unwind it
                            raise
                        row.append(lp)
                        added.append((plane, row, lp))
                        plane.pin[lp] = plane.pin.get(lp, 0) + 1
                        if plane.kind == "state":
                            fresh.append(lp)
                if fresh:
                    plane.aqua.write_local(
                        fresh,
                        jnp.zeros((len(fresh),) + plane.aqua.page_shape,
                                  plane.aqua.dtype))
        except MemoryError:
            for plane, row, lp in reversed(added):
                self._unpin(plane, lp)
                plane.aqua.free([lp])
                row.remove(lp)
            for plane in fresh_rids:
                if not any(plane.pages.get(rid, [])):
                    plane.pages.pop(rid, None)
            raise

    def release(self, rid: int):
        """Drop the request's references. Pages shared with a live request
        survive (the sharer keeps reading them). Tree-indexed pages whose
        LAST reference this drops enter the CACHED state when caching is on
        (refcount 0, slot kept, payload intact — the global prefix cache
        retains them for future adoption) and are freed-with-pruning when it
        is off, so a recycled logical id can never serve a stale prefix
        match. Unindexed pages (decode tails, diverged suffixes) free as
        always."""
        for plane in self.planes.values():
            if rid not in plane.pages:
                continue
            lps = plane.flat(rid)
            if rid in self._active:
                for lp in lps:
                    self._unpin(plane, int(lp))
            indexed = [int(lp) for lp in lps
                       if (plane.name, int(lp)) in self._lp_node]
            plain = [int(lp) for lp in lps
                     if (plane.name, int(lp)) not in self._lp_node]
            plane.aqua.free(plain)
            if self.caching:
                plane.aqua.free_to_cache(indexed)
                # a LOST page cannot be cached — free_to_cache freed it;
                # prune the dead coverage so no arrival adopts it
                for lp in indexed:
                    if plane.aqua.page_table[lp, 0] == -1:
                        self._drop_tree_page(plane.name, lp)
            else:
                for lp in plane.aqua.free(indexed):
                    self._drop_tree_page(plane.name, lp)
            # defensive: no pin may survive the pages it pinned. A release
            # racing a same-step prefetch restore (the engine restored and
            # pinned this rid's pages for the NEXT plan in the step it
            # finished) already unpinned through the active set above, but
            # a pin entry left on a now-freed page would corrupt every
            # later occupant of the recycled id.
            for lp in lps:
                if plane.aqua.page_table[int(lp), 0] == -1:
                    plane.pin.pop(int(lp), None)
            del plane.pages[rid]
        self._active.discard(rid)
        self._req_blocks.pop(rid, None)
        self._req_tokens.pop(rid, None)
        self._req_seed.pop(rid, None)
        self._req_registered.pop(rid, None)

    # -- radix-tree plumbing ----------------------------------------------
    def _radix_walk(self, seed: object, blocks: List[Tuple[int, ...]]
                    ) -> List[Tuple[_RadixNode, int]]:
        """Longest-common-prefix match: descend the seed's tree comparing
        token blocks verbatim; returns one (node, block index) per matched
        block. Divergence mid-edge stops at the last matched block boundary
        — the caller reuses exactly the common prefix."""
        out: List[Tuple[_RadixNode, int]] = []
        node = self._roots.get(seed)
        if node is None:
            return out
        i = 0
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                break
            j = 0
            while (j < len(child.blocks) and i < len(blocks)
                   and child.blocks[j] == blocks[i]):
                out.append((child, j))
                i += 1
                j += 1
            if j < len(child.blocks):
                break                      # diverged mid-edge
            node = child
        return out

    def _split_node(self, node: _RadixNode, at: int):
        """Split an edge at block boundary ``at``: the node keeps blocks
        [:at], a new child carries blocks [at:] with the pages, children and
        LRU stamp of the tail — the structural move behind mid-prompt
        divergence reuse."""
        tail = _RadixNode(blocks=node.blocks[at:], pages=node.pages[at:],
                          parent=node)
        tail.children = node.children
        tail.last_use = node.last_use
        for c in tail.children.values():
            c.parent = tail
        for bi, pagedict in enumerate(tail.pages):
            for name, lps in pagedict.items():
                for lp in lps:
                    self._lp_node[(name, int(lp))] = (tail, bi)
        node.blocks = node.blocks[:at]
        node.pages = node.pages[:at]
        node.children = {tail.blocks[0]: tail}

    def _radix_insert(self, seed: object, blocks: List[Tuple[int, ...]],
                      page_dicts: List[Dict[str, np.ndarray]]):
        """Publish ``blocks`` (with their backing pages) into the seed's
        tree. Blocks already present are skipped (a concurrent twin won the
        publication race — its pages stay canonical); a mid-edge divergence
        splits the edge; the unmatched suffix lands as one new node."""
        root = self._roots.setdefault(seed, _RadixNode())
        node, i = root, 0
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                new = _RadixNode(blocks=list(blocks[i:]),
                                 pages=list(page_dicts[i:]), parent=node)
                new.last_use = self._clock
                node.children[new.blocks[0]] = new
                for bi, pagedict in enumerate(new.pages):
                    for name, lps in pagedict.items():
                        for lp in lps:
                            self._lp_node[(name, int(lp))] = (new, bi)
                return
            j = 0
            while (j < len(child.blocks) and i < len(blocks)
                   and child.blocks[j] == blocks[i]):
                i += 1
                j += 1
            child.last_use = max(child.last_use, self._clock)
            if j == len(child.blocks):
                node = child               # whole edge matched: descend
                continue
            if i == len(blocks):
                return                     # prompt is a prefix of the edge
            self._split_node(child, j)     # diverged mid-edge
            node = child

    def _prune_from(self, node: _RadixNode, bi: int):
        """Remove blocks [bi:] of ``node`` and its ENTIRE subtree from the
        index (every deeper prefix contains the removed block). CACHED
        pages under the cut are dropped back to their free lists — never
        leaked; still-referenced pages are merely un-indexed (their owners
        free them at release). An emptied node unlinks from its parent."""
        key = node.blocks[0] if node.blocks else None
        for child in list(node.children.values()):
            self._prune_from(child, 0)
        node.children.clear()
        for idx in range(bi, len(node.pages)):
            for name, lps in node.pages[idx].items():
                plane = self.planes[name]
                drop = []
                for lp in lps:
                    lp = int(lp)
                    self._lp_node.pop((name, lp), None)
                    if (plane.aqua.page_refs[lp] == 0
                            and plane.aqua.page_table[lp, 0] != -1):
                        drop.append(lp)
                if drop:
                    plane.aqua.drop_cached(drop)
        del node.pages[bi:]
        del node.blocks[bi:]
        if not node.pages and node.parent is not None and key is not None:
            if node.parent.children.get(key) is node:
                node.parent.children.pop(key)
            node.parent = None

    def _drop_tree_page(self, plane_name: str, lp: int):
        """A tree-indexed page went away (freed, or lost with its donor):
        prune its block and everything below it from the index."""
        hit = self._lp_node.get((plane_name, int(lp)))
        if hit is not None:
            self._prune_from(hit[0], hit[1])

    def _iter_nodes(self):
        for root in self._roots.values():
            stack = list(root.children.values())
            while stack:
                n = stack.pop()
                stack.extend(n.children.values())
                yield n

    def _block_cached(self, node: _RadixNode, bi: int) -> bool:
        """True when every page of the block holds zero references — the
        block is retained purely by the cache and may be evicted."""
        for name, lps in node.pages[bi].items():
            if (self.planes[name].aqua.page_refs[np.asarray(lps, np.int64)]
                    != 0).any():
                return False
        return True

    def _cache_reclaim(self, plane_name: str, tier: int, need: int) -> int:
        """The AquaTensor reclaim hook: free ``need`` slots of ``tier`` in
        ``plane_name`` by evicting the coldest cached LEAF blocks (LRU).
        Cold-first demotion: a LOCAL victim demotes to REMOTE-else-HOST and
        a REMOTE victim to HOST when the lower tier has room (the block
        stays adoptable — only its residence degrades, priced as a normal
        coalesced migration); otherwise the block frees outright. tier -1
        requests outright frees (logical-id pressure). Reentrancy-guarded:
        a demotion's own ``_move`` never recurses into eviction."""
        if not self.caching or self._evicting:
            return 0
        self._evicting = True
        try:
            freed = 0
            while freed < need:
                victim = None              # (node, block index)
                for node in self._iter_nodes():
                    if not node.pages:
                        continue
                    # deepest cached block of this node holding pages of
                    # the pressured plane in the pressured tier. The prefix
                    # invariant (a referenced block keeps every ancestor
                    # referenced) means everything at or below a cached
                    # block is itself cached, so an interior block whose
                    # descendants were already demoted to a lower tier is
                    # a legal victim — requiring a childless node here
                    # would strand such blocks forever.
                    for bi in range(len(node.pages) - 1, -1, -1):
                        if not self._block_cached(node, bi):
                            break          # earlier blocks are referenced
                        lps = node.pages[bi].get(plane_name)
                        if lps is None:
                            continue
                        tiers = self.planes[plane_name].aqua.page_table[
                            np.asarray(lps, np.int64), 0]
                        if tier != -1 and not (tiers == tier).any():
                            continue
                        if victim is None or node.last_use < victim[0].last_use:
                            victim = (node, bi)
                        break
                if victim is None:
                    break
                freed += self._evict_block(victim[0], plane_name, tier,
                                           victim[1])
            return freed
        finally:
            self._evicting = False

    def _evict_block(self, node: _RadixNode, plane_name: str,
                     tier: int, bi: Optional[int] = None) -> int:
        """Evict cached block ``bi`` (tail by default) of ``node`` under
        ``tier`` pressure in ``plane_name``. Demotes when the next tier
        down has room (the subtree below stays intact and adoptable),
        frees the block AND its subtree otherwise — everything below a
        cached block is cached too, so nothing referenced is cut. Returns
        slots freed in the pressured tier."""
        if bi is None:
            bi = len(node.pages) - 1
        aqua = self.planes[plane_name].aqua
        lps = np.asarray(node.pages[bi][plane_name], np.int64)
        in_tier = lps[aqua.page_table[lps, 0] == tier] if tier != -1 else lps
        room = 0
        if tier == LOCAL:
            room = aqua.remote_free + len(aqua._free_host)
        elif tier == REMOTE:
            room = len(aqua._free_host)
        if 0 < len(in_tier) <= room:
            aqua._move(in_tier, REMOTE if tier == LOCAL else HOST)
            self.cache_demotions += 1
            return len(in_tier)
        freed = len(in_tier)
        self._prune_from(node, bi)         # drops the cached pages
        self.cache_evictions += 1
        return max(freed, 1)

    def cached_pages(self) -> Dict[str, int]:
        """Refcount-0-but-resident pages per plane (the CACHED state)."""
        return {n: int(((p.aqua.page_refs == 0)
                        & (p.aqua.page_table[:, 0] != -1)).sum())
                for n, p in self.planes.items()}

    # -- prefix sharing (refcounted copy-on-write pages) -------------------
    def adopt_prefix(self, rid: int, tokens: Sequence[int],
                     seed: object = None) -> int:
        """Map a new request's block tables onto already-resident pages for
        the LONGEST COMMON page-aligned prefix of ``tokens`` in the radix
        tree — mid-prompt divergence still reuses every block up to the
        divergence boundary.

        For every matched block the physical pages are taken by reference —
        RETAINED (refcount + 1) when live, REVIVED (a cache hit: refcount
        0 -> 1, the pages were retained past their last referencer) when
        cached — and appended to this request's block-table rows in every
        plane; the chunked-prefill pipeline then starts past the shared
        prefix (the engine sets ``prefill_pos`` accordingly; revived pages
        may sit on a lower tier, so the restore pays only their page-in
        bytes, never prefill FLOPs). Must be called before the request's
        first ``ensure_capacity``.

        Args:
            rid: the request id (no pages allocated yet).
            tokens: the full prompt token ids.
            seed: index partition key (e.g. lora_id) — one tree root per
                seed, so adapters never alias.

        Returns:
            Matched prefix length in TOKENS (a multiple of ``page_tokens``;
            0 when sharing is disabled or nothing matches). The caller must
            still compute at least the final prompt position for logits —
            on a full match that recompute write triggers copy-on-write of
            the tail page (``make_writable``).
        """
        if not self.sharing:
            return 0
        blocks = _token_blocks(tokens, self.page_tokens)
        self._req_blocks[rid] = blocks
        self._req_tokens[rid] = tuple(map(int, tokens))
        self._req_seed[rid] = seed
        matched = self._radix_walk(seed, blocks)
        self._req_registered[rid] = len(matched)
        if not matched:
            return 0
        if any(rid in p.pages for p in self.planes.values()):
            raise ValueError(f"adopt_prefix({rid}) after pages were "
                             "allocated — adoption must precede the first "
                             "ensure_capacity")
        self._clock += 1
        revived_blocks = 0
        for node, bi in matched:
            node.last_use = self._clock
            hit = self._block_cached(node, bi)
            for name, plane in self.planes.items():
                lps = np.asarray(node.pages[bi][name], np.int64)
                if hit:
                    plane.aqua.revive(lps)
                else:
                    refs = plane.aqua.page_refs[lps]
                    cold = lps[refs == 0]
                    if len(cold):          # mixed: revive the cold layers
                        plane.aqua.revive(cold)
                    warm = lps[refs > 0]
                    if len(warm):
                        plane.aqua.retain(warm)
                rows = plane.pages.setdefault(
                    rid, [[] for _ in range(plane.n_layers)])
                for l in range(plane.n_layers):
                    rows[l].append(int(lps[l]))
            if hit:
                revived_blocks += 1
        self.prefix_hits += 1
        self.adopted_tokens += len(matched) * self.page_tokens
        if revived_blocks:
            self.cache_hits += 1
            self.cache_hit_tokens += revived_blocks * self.page_tokens
        return len(matched) * self.page_tokens

    def register_prefix(self, rid: int, n_tokens: int):
        """Publish the request's completed full prompt pages into the radix
        tree (up to ``n_tokens`` positions written so far). Blocks already
        in the tree are skipped (adopted blocks, or a concurrent twin won
        the publication race — its pages stay canonical); a divergence
        mid-edge SPLITS the edge at the block boundary so both branches
        share the common-prefix node. Decode-written pages are never
        registered (the tree covers prompt blocks only). No-op unless
        ``adopt_prefix`` recorded the request's prompt blocks."""
        blocks = self._req_blocks.get(rid)
        if not self.sharing or blocks is None:
            return
        n_full = min(n_tokens // self.page_tokens, len(blocks))
        start = self._req_registered.get(rid, 0)
        if n_full <= start:
            return
        page_dicts: List[Dict[str, np.ndarray]] = []
        for p in range(n_full):
            entry: Dict[str, np.ndarray] = {}
            for name, plane in self.planes.items():
                rows = plane.pages.get(rid)
                if rows is None or len(rows[0]) <= p:
                    return
                entry[name] = np.asarray(
                    [rows[l][p] for l in range(plane.n_layers)], np.int64)
            page_dicts.append(entry)
        self._clock += 1
        self._radix_insert(self._req_seed.get(rid), blocks[:n_full],
                           page_dicts)
        self._req_registered[rid] = max(start, n_full)

    def make_writable(self, rid: int, start: int, end: int):
        """Copy-on-write: before the request writes token positions
        ``[start, end)``, clone any covered page it SHARES (refcount > 1,
        or refcount 1 but radix-indexed — a cache-revived sole referencer
        must not mutate the canonical cached copy) into a fresh exclusive
        LOCAL page and repoint only this request's block-table row at the
        clone. The other referencers (and the radix tree) keep the original
        — a sharer's write can never corrupt the prefix another request is
        still reading or a future arrival will adopt.

        Raises:
            MemoryError: no LOCAL slot is free for a clone.
        """
        if not self.sharing or end <= start:
            return
        p0, p1 = start // self.page_tokens, (end - 1) // self.page_tokens
        for plane in self.planes.values():
            if plane.kind != "tokens":
                continue
            rows = plane.pages.get(rid)
            if not rows:
                continue
            for row in rows:
                for p in range(p0, min(p1 + 1, len(row))):
                    lp = int(row[p])
                    if (int(plane.aqua.refcounts([lp])[0]) <= 1
                            and (plane.name, lp) not in self._lp_node):
                        continue
                    new = int(plane.aqua.allocate(1, prefer=LOCAL)[0])
                    try:
                        if plane.aqua.page_table[new, 0] != LOCAL:
                            plane.aqua.ensure_local([new])
                    except MemoryError:
                        # the clone spilled and cannot be pulled back: hand
                        # it straight back instead of leaking it (the block
                        # table still points at the shared original)
                        plane.aqua.free([new])
                        raise
                    plane.aqua.write_local([new], plane.aqua.read([lp]))
                    if rid in self._active:
                        self._unpin(plane, lp)
                        plane.pin[new] = plane.pin.get(new, 0) + 1
                    # deref the original; sharers keep it, and if this was
                    # its last reference an indexed page stays CACHED (or
                    # prunes its coverage when caching is off)
                    if (self.caching
                            and (plane.name, lp) in self._lp_node):
                        plane.aqua.free_to_cache([lp])
                    else:
                        for f in plane.aqua.free([lp]):
                            self._drop_tree_page(plane.name, f)
                    row[p] = new
                    self.cow_copies += 1

    def shared_pages_with(self, rid: int, other_rids: Sequence[int]
                          ) -> np.ndarray:
        """Per-plane count of this request's pages also referenced by any of
        ``other_rids`` — the physical-page discount the schedulers apply
        when budgeting a run set that contains both sharers."""
        out = []
        for plane in self.planes.values():
            mine = plane.pages.get(rid)
            if not mine:
                out.append(0)
                continue
            mine_set = {lp for row in mine for lp in row}
            shared = set()
            for o in other_rids:
                for row in plane.pages.get(o, []):
                    shared.update(mine_set.intersection(row))
            out.append(len(shared))
        return np.asarray(out, np.int64)

    def prefix_group_of(self, rid: int) -> Optional[object]:
        """Co-scheduling identity: the root-edge radix node of the
        request's prompt (same node <=> same seed and at least the first
        prompt block in common — every sharer of any deeper prefix shares
        that root edge too). The schedulers cluster same-group requests
        inside a fairness class so a shared prefix parks/restores once per
        plan. None when sharing is off or the prompt has no indexed
        coverage."""
        if not self.sharing:
            return None
        blocks = self._req_blocks.get(rid)
        if not blocks:
            return None
        root = self._roots.get(self._req_seed.get(rid))
        if root is None:
            return None
        return root.children.get(blocks[0])

    def cow_reserve(self) -> np.ndarray:
        """Per-plane pages a pending copy-on-write may allocate (one clone
        per layer row of each token plane): the scheduler headroom for a
        fully-matched request that must still recompute its final prompt
        position."""
        return np.asarray([p.n_layers if p.kind == "tokens" else 0
                           for p in self.planes.values()], np.int64)

    def physical_pages(self) -> Dict[str, int]:
        """Allocated PHYSICAL pages per plane (a page shared by N block
        tables counts once) — what eviction and MemoryError accounting see."""
        return {n: int((p.aqua.page_table[:, 0] != -1).sum())
                for n, p in self.planes.items()}

    def logical_pages(self) -> Dict[str, int]:
        """Block-table page references per plane (a page shared by N block
        tables counts N times) — the unshared footprint for comparison."""
        return {n: sum(len(row) for rows in p.pages.values() for row in rows)
                for n, p in self.planes.items()}

    # -- block tables (the step-program operands) --------------------------
    def block_tables_prefill(self, rid: int, pad_to: Optional[int] = None
                             ) -> Dict[str, jnp.ndarray]:
        """One request's tables from position 0: token planes as
        (G, n_sub, pad_to) physical LOCAL slots, scratch-padded; state
        planes as (G, n_sub) bare slots. Chunked prefill passes a FIXED
        ``pad_to`` (pps plus the write-window spill) so every chunk of every
        request shares one table shape — no retrace per context length."""
        out = {}
        for name, plane in self.planes.items():
            rows = plane.pages[rid]
            if plane.kind == "tokens":
                bt = plane.aqua.block_tables(rows,
                                             pad_to=pad_to or len(rows[0]),
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub, -1))
            else:
                bt = plane.aqua.block_tables(rows, pad_to=1,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub))
        return out

    def block_tables(self, lane_rids: Sequence[Optional[int]],
                     pad_to: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        """Batched row query (decode lanes, or the fused step's packed
        decode+chunk rows): token planes as (G, n_sub, B, pad_to) physical
        LOCAL slots (``pad_to`` defaults to ``pps``; the fused step passes
        ``pps`` plus the chunk write-window spill so every row shares one
        shape), state planes as (G, n_sub, B); empty lanes and padding
        point at each plane's scratch page."""
        B = len(lane_rids)
        tok_pad = pad_to or self.pps
        out = {}
        for name, plane in self.planes.items():
            rows: List[List[int]] = []
            for l in range(plane.n_layers):
                for rid in lane_rids:
                    rows.append(plane.pages[rid][l] if rid is not None else [])
            if plane.kind == "tokens":
                bt = plane.aqua.block_tables(rows, pad_to=tok_pad,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(
                    bt.reshape(self.G, plane.n_sub, B, tok_pad))
            else:
                bt = plane.aqua.block_tables(rows, pad_to=1,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub, B))
        return out

    # -- tier migration (preempt / restore as page-table flips) ------------
    def park(self, rid: int, n_tokens: int, *, prefer: int = REMOTE):
        """Preempt: flip the request's pages out of LOCAL — ALL planes fused
        into one coalesced message per (tier, donor) group (a hybrid's kv +
        ssm + conv pages ride one staging buffer, not one message per
        plane), token pages metered at their fill, state pages whole (they
        are always fully live).

        ``n_tokens`` is the context actually RESIDENT in the pools (for an
        engine request at ctx_len that is ctx_len-1: the newest token's
        state lands at its next decode step). A token page allocated ahead
        of a boundary but not yet written moves at fill 0.

        Shared pages move ONCE: parking drops this request's LOCAL pin, and
        only pages whose pin count reaches zero (no other active sharer) are
        offloaded — a shared prefix page leaves LOCAL when its LAST active
        referencer parks, and is metered full (its payload is complete
        whatever this request's own resident prefix is).
        """
        with self.meter.coalesce():
            for plane in self.planes.values():
                if rid not in plane.pages:
                    continue
                if plane.kind == "tokens":
                    for row in plane.pages[rid]:
                        fills = np.clip(
                            n_tokens - np.arange(len(row)) * self.page_tokens,
                            0, self.page_tokens) / self.page_tokens
                        # shared prefix pages are always fully written (only
                        # full prompt pages enter the index)
                        fills = np.where(plane.aqua.refcounts(row) > 1,
                                         1.0, fills)
                        plane.aqua.set_page_fill(row, fills)
                lps = plane.flat(rid)
                if rid in self._active:
                    for lp in lps:
                        self._unpin(plane, int(lp))
                victims = [int(lp) for lp in lps
                           if plane.pin.get(int(lp), 0) == 0]
                if victims:
                    plane.aqua.offload(np.asarray(victims, np.int64),
                                       prefer=prefer)
        self._active.discard(rid)

    def restore(self, rid: int):
        """Make every page of the request LOCAL and pin it there (no bytes
        move for pages a still-active sharer kept LOCAL); resets token-page
        fills to 1.0. No-op when the request is already active."""
        self._activate(rid)

    def nonlocal_pages(self, rid: int) -> np.ndarray:
        """Per-plane pages of the request currently NOT in the LOCAL tier."""
        out = []
        for plane in self.planes.values():
            rows = plane.aqua.page_table[plane.flat(rid)]
            out.append(int((rows[:, 0] != LOCAL).sum()) if len(rows) else 0)
        return np.asarray(out, np.int64)

    def local_headroom(self) -> np.ndarray:
        """Per-plane LOCAL slots obtainable without touching live pages:
        free slots plus cached (refcount-0) LOCAL pages, which eviction
        demotes or drops on demand."""
        out = []
        for p in self.planes.values():
            free = p.aqua.local_free
            if self.caching:
                free += int(((p.aqua.page_refs == 0)
                             & (p.aqua.page_table[:, 0] == LOCAL)).sum())
            out.append(free)
        return np.asarray(out, np.int64)

    def can_restore(self, rid: int) -> bool:
        """True when a restore fits every plane's obtainable LOCAL slots
        right now (free plus evictable cache — cached pages yield to a real
        restore) — the prefetch guard: an early ``ensure_local`` must never
        steal pages the current run set still needs (it would raise
        mid-step)."""
        return bool(np.all(self.nonlocal_pages(rid) <= self.local_headroom()))

    # -- coordinator-driven lease plumbing --------------------------------
    def add_remote_lease(self, donor: str, nbytes: float):
        """Split a donor's byte grant across the planes in proportion to
        their share of a full-length request's footprint. Slots are floored
        per plane so the booked capacity never exceeds the grant the
        coordinator accounts (a plane whose share rounds to zero simply
        gets no pool from this donor and falls through to the host tier);
        a grant too small for any plane's page goes whole to the
        largest-weight plane, matching the old single-pool ``max(1, ...)``."""
        weights = {n: float(self._plane_pages(p, self.max_seq)
                            * p.aqua.page_bytes)
                   for n, p in self.planes.items()}
        total = sum(weights.values())
        slots = {n: int(nbytes * weights[n] / total // p.aqua.page_bytes)
                 for n, p in self.planes.items()}
        if not any(slots.values()):
            slots[max(weights, key=weights.get)] = 1
        for name, n_slots in slots.items():
            if n_slots > 0:
                self.planes[name].aqua.add_remote_lease(donor, n_slots)

    def evict_remote(self, donor: str) -> int:
        """Honor a donor reclaim: evacuate every PHYSICAL page parked on the
        donor's pools to the host tier and drop the lease (the paper's
        iteration-boundary ``aqua.respond()``). A page shared by several
        block tables moves once. Returns pages moved.

        Raises:
            MemoryError: the host tier cannot absorb the evacuation.
        """
        with self.meter.coalesce():
            return sum(p.aqua.evict_remote(donor)
                       for p in self.planes.values()
                       if donor in p.aqua.remote_pools)

    # -- fault plumbing (lease revocation, donor loss) ---------------------
    def attach_faults(self, faults) -> None:
        """Share one ``core/faults.FaultInjector`` with every plane's tensor
        (transfer-leg retry consults) and the mesh domain (lease-boundary
        guards on the collective legs)."""
        self.faults = faults
        for plane in self.planes.values():
            plane.aqua.faults = faults
        if self.mesh is not None:
            self.mesh.attach_faults(faults)

    def shrink_lease(self, donor: str, frac: float) -> int:
        """Dynamic donor-side memory pressure: the donor reclaims ``frac``
        of its leased slots in EVERY plane, NOW (unlike ``evict_remote``
        this is partial, and unlike the coordinator reclaim poll it is not
        deferred to a respond boundary — the donor's own traffic needs the
        HBM). Occupied reclaimed slots live-migrate to the remaining donors
        or the host tier, all planes fused into one coalesced message per
        (tier, donor) group. Returns pages migrated.

        Raises:
            LeaseRevokedError: no live lease from this donor in any plane.
            MemoryError: the surviving tiers cannot absorb the migration.
        """
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"shrink fraction {frac} not in (0, 1]")
        holders = [p for p in self.planes.values()
                   if donor in p.aqua.remote_pools]
        if not holders:
            raise LeaseRevokedError(
                f"shrink of donor {donor} without a live lease in any plane",
                donor=donor)
        moved = 0
        with self.meter.coalesce():
            for plane in holders:
                n = math.ceil(frac * plane.aqua.remote_capacity[donor])
                moved += plane.aqua.shrink_lease(donor, n)
        return moved

    def fail_donor(self, donor: str) -> List[int]:
        """Permanent donor loss: every page resident on the donor (every
        plane) flips to the LOST tier and the leases drop. Returns the
        sorted rids of VICTIM requests — those whose block tables reference
        a lost page — for the engine's recompute-from-prompt recovery.
        Radix coverage backed by lost pages is pruned immediately — CACHED
        pages on the dead slab are DROPPED with it (their only copy died;
        leaking their logical ids would bleed the pool one donor death at a
        time) — so no later arrival can adopt a dead prefix."""
        victims: set = set()
        for plane in self.planes.values():
            if donor not in plane.aqua.remote_pools:
                continue
            lost = set(int(l) for l in plane.aqua.fail_donor(donor))
            if not lost:
                continue
            for lp in lost:
                self._drop_tree_page(plane.name, lp)
            for rid, rows in plane.pages.items():
                if any(int(lp) in lost for row in rows for lp in row):
                    victims.add(rid)
        if self.faults is not None:
            self.faults.mark_donor_lost(donor)
        return sorted(victims)

    def total_capacity(self) -> np.ndarray:
        """Per-plane PHYSICAL slots across every live tier (scratch
        excluded): what the runtime can hold AT ALL, LOCAL or parked. The
        engine re-plans the scheduler budget against this after a lease
        shrinks or a donor dies — admission must contract when the tiers
        backing preemption vanish."""
        return np.asarray(
            [p.aqua.local_pool.shape[0] - 1 + p.aqua.host_pool.shape[0]
             + sum(p.aqua.remote_capacity.values())
             for p in self.planes.values()], np.int64)

    def stats(self) -> Dict:
        """Tier occupancy per plane, transfer-meter totals, and the prefix-
        sharing counters (hits, adopted tokens, copy-on-write clones,
        physical vs logical page counts)."""
        tiers: Dict[str, int] = {}
        for p in self.planes.values():
            for k, v in p.aqua.tier_counts().items():
                tiers[k] = tiers.get(k, 0) + v
        return {"tiers": tiers,
                "planes": {n: p.aqua.tier_counts()
                           for n, p in self.planes.items()},
                "page_tokens": self.page_tokens,
                "sharing": {"enabled": self.sharing,
                            "prefix_hits": self.prefix_hits,
                            "adopted_tokens": self.adopted_tokens,
                            "cow_copies": self.cow_copies,
                            "physical_pages": self.physical_pages(),
                            "logical_pages": self.logical_pages()},
                "cache": {"enabled": self.caching,
                          "hits": self.cache_hits,
                          "hit_tokens": self.cache_hit_tokens,
                          "evictions": self.cache_evictions,
                          "demotions": self.cache_demotions,
                          "cached_pages": self.cached_pages(),
                          "nodes": sum(1 for _ in self._iter_nodes())},
                "meter": {"bytes_fabric": self.meter.bytes_fabric,
                          "bytes_host": self.meter.bytes_host,
                          "messages_fabric": self.meter.messages_fabric,
                          "messages_host": self.meter.messages_host,
                          "retries_fabric": self.meter.retries_fabric,
                          "retries_host": self.meter.retries_host,
                          "sim_time": self.meter.sim_time}}

    # -- crash-consistent snapshot / restore --------------------------------
    _SNAP_COUNTERS = ("prefix_hits", "adopted_tokens", "cow_copies",
                      "cache_hits", "cache_hit_tokens", "cache_evictions",
                      "cache_demotions")

    def snapshot_state(self) -> Dict:
        """Serialize the runtime's full serving state to a plain dict:
        per-request block tables, every referenced page's PAYLOAD (gathered
        from whatever tier it sits on, each physical page captured once
        however many block tables alias it), the radix prefix tree with its
        per-block page sets, the per-request prompt records behind
        ``register_prefix``, and the sharing/cache counters.

        Logical page ids in the snapshot are snapshot-relative:
        :meth:`restore_state` re-allocates pages on a fresh runtime and
        remaps every reference, so the snapshot survives any allocator
        history. Call between engine steps only (no step program in
        flight); LOST pages cannot be captured — recovery must re-queue
        their victims first (``read`` raises on them, loudly).
        """
        def ser_node(node: _RadixNode) -> Dict:
            return {"blocks": [list(b) for b in node.blocks],
                    "pages": [{n: [int(x) for x in lps]
                               for n, lps in pd.items()}
                              for pd in node.pages],
                    "last_use": int(node.last_use),
                    "children": [ser_node(c)
                                 for c in node.children.values()]}

        tree_lps: Dict[str, set] = {name: set() for name in self.planes}
        for node in self._iter_nodes():
            for pd in node.pages:
                for n, lps in pd.items():
                    tree_lps[n].update(int(x) for x in lps)
        planes: Dict[str, Dict] = {}
        for name, plane in self.planes.items():
            rows = {int(rid): [[int(lp) for lp in row] for row in rws]
                    for rid, rws in plane.pages.items()}
            lps = sorted({lp for rws in rows.values()
                          for row in rws for lp in row} | tree_lps[name])
            planes[name] = {
                "pages": rows, "lps": lps,
                "data": (np.asarray(plane.aqua.read(lps)) if lps
                         else None),
                "fills": (plane.aqua.page_fill[
                    np.asarray(lps, np.int64)].tolist() if lps else [])}
        return {
            "version": 1,
            "planes": planes,
            "tree": [{"seed": seed,
                      "children": [ser_node(c)
                                   for c in root.children.values()]}
                     for seed, root in self._roots.items()],
            "req_blocks": {int(r): [list(b) for b in bl]
                           for r, bl in self._req_blocks.items()},
            "req_tokens": {int(r): list(t)
                           for r, t in self._req_tokens.items()},
            "req_seed": dict(self._req_seed),
            "req_registered": dict(self._req_registered),
            "clock": int(self._clock),
            "counters": {k: getattr(self, k) for k in self._SNAP_COUNTERS}}

    def restore_state(self, snap: Dict) -> None:
        """Rebuild a :meth:`snapshot_state` dict on a FRESH runtime of the
        same configuration and geometry.

        Every snapshot page is re-allocated preferring the HOST tier (the
        crash-safe landing zone; the fallback ladder spills to surviving
        remote leases, then LOCAL) and its payload written back verbatim,
        unmetered — a restore is reconstruction, not traffic. Refcounts are
        reconstructed exactly: one reference per block table aliasing the
        page, plus the CACHED state (refcount 0, slot kept) for pages owned
        purely by the radix index. The tree, its reverse map, the prompt
        records and the counters are rebuilt with the remapped ids. NO
        request is active afterwards (pins empty): the engine re-queues
        every in-flight request as parked and the normal placement path
        pulls its pages LOCAL on its next admission.

        Raises:
            ValueError: this runtime already holds request state (restore
                targets a fresh engine, never a live one).
        """
        if (any(p.pages for p in self.planes.values()) or self._roots
                or self._active):
            raise ValueError(f"{self.cfg.name}: restore_state on a runtime "
                             "already holding request state — restore "
                             "targets a FRESH engine")
        maps: Dict[str, Dict[int, int]] = {}
        for name, ps in snap["planes"].items():
            plane = self.planes[name]
            ref_rids: Dict[int, set] = {}
            for rid, rws in ps["pages"].items():
                for row in rws:
                    for lp in row:
                        ref_rids.setdefault(int(lp), set()).add(int(rid))
            lp_map: Dict[int, int] = {}
            old_lps = [int(x) for x in ps["lps"]]
            if old_lps:
                new = plane.aqua.allocate(len(old_lps), prefer=HOST)
                plane.aqua.write(new, jnp.asarray(ps["data"]), meter=False)
                plane.aqua.set_page_fill(new, np.asarray(ps["fills"]))
                cached: List[int] = []
                for old, nlp in zip(old_lps, new):
                    nlp = int(nlp)
                    lp_map[old] = nlp
                    k = len(ref_rids.get(old, ()))
                    if k == 0:
                        cached.append(nlp)   # tree-owned: CACHED, ref 0
                    for _ in range(k - 1):   # one ref per aliasing table
                        plane.aqua.retain([nlp])
                if cached:
                    plane.aqua.free_to_cache(cached)
            for rid, rws in ps["pages"].items():
                plane.pages[int(rid)] = [[lp_map[int(lp)] for lp in row]
                                         for row in rws]
            maps[name] = lp_map

        def build(d: Dict, parent: _RadixNode) -> _RadixNode:
            node = _RadixNode(
                blocks=[tuple(int(t) for t in b) for b in d["blocks"]],
                pages=[{n: np.asarray([maps[n][int(x)] for x in lps],
                                      np.int64)
                        for n, lps in pd.items()} for pd in d["pages"]],
                parent=parent)
            node.last_use = int(d["last_use"])
            for cd in d["children"]:
                c = build(cd, node)
                node.children[c.blocks[0]] = c
            return node

        for entry in snap["tree"]:
            root = _RadixNode()
            for cd in entry["children"]:
                c = build(cd, root)
                root.children[c.blocks[0]] = c
            self._roots[entry["seed"]] = root
        for node in self._iter_nodes():
            for bi, pd in enumerate(node.pages):
                for n, lps in pd.items():
                    for lp in lps:
                        self._lp_node[(n, int(lp))] = (node, bi)
        self._req_blocks = {int(r): [tuple(int(t) for t in b) for b in bl]
                            for r, bl in snap["req_blocks"].items()}
        self._req_tokens = {int(r): tuple(int(t) for t in ts)
                            for r, ts in snap["req_tokens"].items()}
        self._req_seed = dict(snap["req_seed"])
        self._req_registered = {int(r): int(v)
                                for r, v in snap["req_registered"].items()}
        self._clock = int(snap["clock"])
        for k in self._SNAP_COUNTERS:
            setattr(self, k, snap["counters"][k])
