"""Unified paged state runtime: EVERY family's dynamic context on AquaTensor
pages, behind per-request block tables.

``PagedStateRuntime`` is the serving engine's state manager (paper §3 + §5
made structural, for the paper's whole model zoo): each family's per-request
dynamic context is decomposed by ``models/lm.py:paged_layout`` into page
PLANES — one tiered AquaTensor pool per plane, native-dtype payloads:

    kv     (2, n_kv, page, hd)   attention K/V, ceil(ctx/page) pages/layer
    mla    (page, kv_lora+rope)  fused MLA latent + roped key, token-paged
    ssm    (d_inner, d_state)    Mamba SSM state (f32), one page/layer
    conv   (d_conv-1, d_inner)   Mamba conv tail, one page/layer
    wkv    (H, hd, hd)           RWKV6 wkv state (f32), one page/layer
    shift  (2, d_model)          RWKV6 time/channel-mix shifts, one page/layer

A hybrid (Jamba) request owns kv pages for its attention sub-layers and
ssm/conv pages for the Mamba ones; an RWKV6 request owns only fixed-size
state pages (O(1) context). Decode/prefill read and write the LOCAL pools
directly inside the jit'd whole-step programs (attention through the
``kernels/paged_attention`` block-table kernels, MLA/recurrent planes via
shape-stable jnp gathers/scatters), so preemption is a *page-table tier
flip* for every family:

    park    = offload(pages)      one coalesced message per
    restore = ensure_local(pages) (plane, tier, donor) group

— no gather of cache leaves, no float32 blob, no repacking, for ANY family.
Partial token-plane tails are metered at their valid fraction, so a parked
request moves exactly its native-dtype context footprint. The seed-era dense
blob-store shim this replaces is deleted; there is exactly one way a
request's state moves between tiers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import (AquaTensor, LOCAL, REMOTE, TransferMeter)


@dataclass
class _Plane:
    """One page plane: an AquaTensor pool + the per-request page bookkeeping."""
    name: str
    kind: str                        # "tokens" | "state"
    aqua: AquaTensor
    n_layers: int                    # plane layers across the whole stack
    n_sub: int                       # plane sub-layers per group
    token_bytes: int = 0             # per-layer bytes/token (token planes)
    scratch_lp: int = 0
    pages: Dict[int, List[List[int]]] = field(default_factory=dict)

    @property
    def scratch_slot(self) -> int:
        return int(self.aqua.page_table[self.scratch_lp, 1])

    def flat(self, rid: int) -> np.ndarray:
        return np.asarray([lp for row in self.pages.get(rid, [])
                           for lp in row], np.int64)


class PagedStateRuntime:
    """Family-agnostic block-table state manager on tiered AquaTensor pools."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int,
                 page_tokens: int = 8, local_pages: Optional[int] = None,
                 host_pages: int = 8192, n_logical: int = 16384,
                 max_running: int = 4, meter: Optional[TransferMeter] = None):
        from repro.models import lm
        if not lm.supports_paged(cfg):
            raise ValueError(f"{cfg.name}: not paged-servable (windowed "
                             "ring-buffer / softcap / encdec layers have no "
                             "page plane yet)")
        self.cfg = cfg
        self.G = lm.n_groups(cfg)
        self.gs = lm.group_size(cfg)
        self.page_tokens = page_tokens
        self.max_seq = max_seq
        self.pps = math.ceil(max_seq / page_tokens)
        self.meter = meter or TransferMeter()
        self.planes: Dict[str, _Plane] = {}
        for name, spec in lm.paged_layout(cfg).items():
            n_sub = len(spec["positions"])
            n_layers = self.G * n_sub
            if spec["kind"] == "tokens":
                if name == "kv":
                    K, hd = spec["dims"]
                    page_shape: Tuple[int, ...] = (2, K, page_tokens, hd)
                else:                                   # mla latent plane
                    (C,) = spec["dims"]
                    page_shape = (page_tokens, C)
                per_req = n_layers * self.pps
                # token-plane LOCAL budget is caller-tunable (the admission
                # gate the schedulers plan against); +1 is the scratch page
                slots = (local_pages if local_pages is not None
                         else max_running * per_req + 1)
            else:
                page_shape = spec["shape"]
                per_req = n_layers
                slots = max_running * per_req + 1
            aqua = AquaTensor(n_logical=n_logical, page_shape=page_shape,
                              local_slots=slots, host_slots=host_pages,
                              dtype=spec["dtype"], meter=self.meter,
                              name=f"{cfg.name}/{name}")
            plane = _Plane(name, spec["kind"], aqua, n_layers, n_sub,
                           token_bytes=spec.get("token_bytes", 0))
            # pinned LOCAL dummy page: idle batch lanes and block-table
            # padding point here so masked DMAs / idle-lane state reads and
            # writes stay in-bounds
            plane.scratch_lp = int(aqua.allocate(1, prefer=LOCAL)[0])
            self.planes[name] = plane

    # -- geometry ---------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Token-plane pages per layer covering n_tokens."""
        return max(1, math.ceil(n_tokens / self.page_tokens))

    def _plane_pages(self, plane: _Plane, n_tokens: int) -> int:
        if plane.kind == "tokens":
            return plane.n_layers * self.pages_for(n_tokens)
        return plane.n_layers

    def pages_per_request(self, n_tokens: int) -> np.ndarray:
        """Per-plane page cost of a request at n_tokens of context — the
        vector the schedulers budget against (one entry per plane)."""
        return np.asarray([self._plane_pages(p, n_tokens)
                           for p in self.planes.values()], np.int64)

    def footprint_bytes(self, n_tokens: int) -> float:
        """Native-dtype whole-context bytes of a request (no page slack):
        token planes at n_tokens, recurrent state planes at their fixed
        size. This is exactly what one park/restore moves."""
        total = 0.0
        for p in self.planes.values():
            if p.kind == "tokens":
                total += p.n_layers * n_tokens * p.token_bytes
            else:
                total += p.n_layers * p.aqua.page_bytes
        return float(total)

    def footprint_elems(self, n_tokens: int) -> int:
        """Element count of the same footprint (the seed blob path moved
        4 bytes per element, whatever the native dtype)."""
        total = 0
        for p in self.planes.values():
            per_page = int(np.prod(p.aqua.page_shape))
            if p.kind == "tokens":
                total += p.n_layers * n_tokens * (p.token_bytes
                                                  // p.aqua.dtype.itemsize)
            else:
                total += p.n_layers * per_page
        return total

    @property
    def page_budget(self) -> np.ndarray:
        """Per-plane LOCAL pages available to requests (scratch excluded)."""
        return np.asarray([p.aqua.local_pool.shape[0] - 1
                           for p in self.planes.values()], np.int64)

    @property
    def aqua(self) -> AquaTensor:
        """The sole plane's tensor — attention-only (or ssm-state-only)
        convenience for tests/benchmarks; multi-plane runtimes must address
        ``planes[name].aqua`` explicitly."""
        if len(self.planes) != 1:
            raise AttributeError("runtime has multiple planes; use "
                                 f".planes[name].aqua ({list(self.planes)})")
        return next(iter(self.planes.values())).aqua

    # -- pool plumbing (the jit operands) ---------------------------------
    @property
    def pools(self) -> Dict[str, jnp.ndarray]:
        return {n: p.aqua.local_pool for n, p in self.planes.items()}

    @pools.setter
    def pools(self, value: Dict[str, jnp.ndarray]):
        for n, pool in value.items():
            self.planes[n].aqua.local_pool = pool

    # -- allocation -------------------------------------------------------
    def ensure_capacity(self, rid: int, n_tokens: int):
        """Grow the request's block tables to cover n_tokens: token planes
        add pages as the context crosses page boundaries; state planes
        allocate their fixed page set on first touch (zeroed — a freed slot
        may hold a previous occupant's state, and the zero page IS the
        initial recurrent state).

        New pages must be LOCAL (the step programs read the LOCAL pools): if
        the allocator had to spill a fresh page to another tier the LOCAL
        pool is full and no later step could pull it back either, so fail
        loudly here with the tensor/tier MemoryError. The page-budget-aware
        schedulers are designed to keep planned run sets below this point.
        """
        for plane in self.planes.values():
            rows = plane.pages.setdefault(
                rid, [[] for _ in range(plane.n_layers)])
            need = (self.pages_for(n_tokens) if plane.kind == "tokens" else 1)
            fresh: List[int] = []
            for row in rows:
                while len(row) < need:
                    lp = int(plane.aqua.allocate(1, prefer=LOCAL)[0])
                    if plane.aqua.page_table[lp, 0] != LOCAL:
                        plane.aqua.ensure_local([lp])  # raises: LOCAL is full
                    row.append(lp)
                    if plane.kind == "state":
                        fresh.append(lp)
            if fresh:
                plane.aqua.write_local(
                    fresh, jnp.zeros((len(fresh),) + plane.aqua.page_shape,
                                     plane.aqua.dtype))

    def release(self, rid: int):
        for plane in self.planes.values():
            if rid in plane.pages:
                plane.aqua.free(plane.flat(rid))
                del plane.pages[rid]

    # -- block tables (the step-program operands) --------------------------
    def block_tables_prefill(self, rid: int, pad_to: Optional[int] = None
                             ) -> Dict[str, jnp.ndarray]:
        """One request's tables from position 0: token planes as
        (G, n_sub, pad_to) physical LOCAL slots, scratch-padded; state
        planes as (G, n_sub) bare slots. Chunked prefill passes a FIXED
        ``pad_to`` (pps plus the write-window spill) so every chunk of every
        request shares one table shape — no retrace per context length."""
        out = {}
        for name, plane in self.planes.items():
            rows = plane.pages[rid]
            if plane.kind == "tokens":
                bt = plane.aqua.block_tables(rows,
                                             pad_to=pad_to or len(rows[0]),
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub, -1))
            else:
                bt = plane.aqua.block_tables(rows, pad_to=1,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub))
        return out

    def block_tables(self, lane_rids: Sequence[Optional[int]]
                     ) -> Dict[str, jnp.ndarray]:
        """Batched decode query: token planes as (G, n_sub, B, pps) physical
        LOCAL slots, state planes as (G, n_sub, B); empty lanes and padding
        point at each plane's scratch page."""
        B = len(lane_rids)
        out = {}
        for name, plane in self.planes.items():
            rows: List[List[int]] = []
            for l in range(plane.n_layers):
                for rid in lane_rids:
                    rows.append(plane.pages[rid][l] if rid is not None else [])
            if plane.kind == "tokens":
                bt = plane.aqua.block_tables(rows, pad_to=self.pps,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(
                    bt.reshape(self.G, plane.n_sub, B, self.pps))
            else:
                bt = plane.aqua.block_tables(rows, pad_to=1,
                                             pad_slot=plane.scratch_slot)
                out[name] = jnp.asarray(bt.reshape(self.G, plane.n_sub, B))
        return out

    # -- tier migration (preempt / restore as page-table flips) ------------
    def park(self, rid: int, n_tokens: int, *, prefer: int = REMOTE):
        """Preempt: flip the request's pages out of LOCAL — one coalesced
        message per (plane, tier, donor) group, token pages metered at their
        fill, state pages whole (they are always fully live).

        ``n_tokens`` is the context actually RESIDENT in the pools (for an
        engine request at ctx_len that is ctx_len-1: the newest token's
        state lands at its next decode step). A token page allocated ahead
        of a boundary but not yet written moves at fill 0.
        """
        for plane in self.planes.values():
            if rid not in plane.pages:
                continue
            if plane.kind == "tokens":
                for row in plane.pages[rid]:
                    fills = np.clip(
                        n_tokens - np.arange(len(row)) * self.page_tokens,
                        0, self.page_tokens) / self.page_tokens
                    plane.aqua.set_page_fill(row, fills)
            plane.aqua.offload(plane.flat(rid), prefer=prefer)

    def restore(self, rid: int):
        """Make every page of the request LOCAL (no-op when already there)."""
        for plane in self.planes.values():
            if rid not in plane.pages:
                continue
            plane.aqua.ensure_local(plane.flat(rid))
            for row in plane.pages[rid]:
                plane.aqua.set_page_fill(row, 1.0)

    def nonlocal_pages(self, rid: int) -> np.ndarray:
        """Per-plane pages of the request currently NOT in the LOCAL tier."""
        out = []
        for plane in self.planes.values():
            rows = plane.aqua.page_table[plane.flat(rid)]
            out.append(int((rows[:, 0] != LOCAL).sum()) if len(rows) else 0)
        return np.asarray(out, np.int64)

    def can_restore(self, rid: int) -> bool:
        """True when a restore fits every plane's free LOCAL slots right now
        — the prefetch guard: an early ``ensure_local`` must never steal
        pages the current run set still needs (it would raise mid-step)."""
        free = np.asarray([p.aqua.local_free for p in self.planes.values()])
        return bool(np.all(self.nonlocal_pages(rid) <= free))

    # -- coordinator-driven lease plumbing --------------------------------
    def add_remote_lease(self, donor: str, nbytes: float):
        """Split a donor's byte grant across the planes in proportion to
        their share of a full-length request's footprint. Slots are floored
        per plane so the booked capacity never exceeds the grant the
        coordinator accounts (a plane whose share rounds to zero simply
        gets no pool from this donor and falls through to the host tier);
        a grant too small for any plane's page goes whole to the
        largest-weight plane, matching the old single-pool ``max(1, ...)``."""
        weights = {n: float(self._plane_pages(p, self.max_seq)
                            * p.aqua.page_bytes)
                   for n, p in self.planes.items()}
        total = sum(weights.values())
        slots = {n: int(nbytes * weights[n] / total // p.aqua.page_bytes)
                 for n, p in self.planes.items()}
        if not any(slots.values()):
            slots[max(weights, key=weights.get)] = 1
        for name, n_slots in slots.items():
            if n_slots > 0:
                self.planes[name].aqua.add_remote_lease(donor, n_slots)

    def evict_remote(self, donor: str) -> int:
        return sum(p.aqua.evict_remote(donor)
                   for p in self.planes.values()
                   if donor in p.aqua.remote_pools)

    def stats(self) -> Dict:
        tiers: Dict[str, int] = {}
        for p in self.planes.values():
            for k, v in p.aqua.tier_counts().items():
                tiers[k] = tiers.get(k, 0) + v
        return {"tiers": tiers,
                "planes": {n: p.aqua.tier_counts()
                           for n, p in self.planes.items()},
                "page_tokens": self.page_tokens,
                "meter": {"bytes_fabric": self.meter.bytes_fabric,
                          "bytes_host": self.meter.bytes_host,
                          "messages_fabric": self.meter.messages_fabric,
                          "messages_host": self.meter.messages_host,
                          "sim_time": self.meter.sim_time}}
