"""Page-native decode KV: block tables over AquaTensor page pools.

``PagedKVRuntime`` is the serving engine's KV manager (paper §3 + §5 made
structural): per-layer K/V pages for every request live in ONE fused
page-major AquaTensor pool — payload ``(2, n_kv, page, hd)`` in the model's
native dtype — and each request owns a per-layer block table of logical page
ids. Decode attention reads the LOCAL pool through the
``kernels/paged_attention`` block-table kernel; prefill writes pages
directly; a decode step appends the new token's K/V into the request's tail
page via the page-append writer op.

Preemption is therefore a *page-table tier flip*:

    park    = AquaTensor.offload(pages)      one coalesced message per
    restore = AquaTensor.ensure_local(pages) (tier, donor) group

— no gather of cache leaves, no float32 blob, no repacking. The partial tail
page is metered at its valid fraction, so a parked request moves exactly its
native-dtype KV footprint.

``ContextStore`` (below) is the seed blob path, kept as the compatibility
shim for families whose decode state is not plain paged KV (RWKV/Mamba
state, MLA latent caches, ring-buffer windowed layers) and as the
"what AQUA replaces" baseline in benchmarks/context_switch.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import (AquaTensor, LOCAL, REMOTE, TransferMeter)


class PagedKVRuntime:
    """Block-table KV manager on a tiered AquaTensor page pool."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int,
                 page_tokens: int = 8, local_pages: Optional[int] = None,
                 host_pages: int = 8192, n_logical: int = 16384,
                 max_running: int = 4, meter: Optional[TransferMeter] = None):
        from repro.models import lm
        if not lm.supports_paged_kv(cfg):
            raise ValueError(f"{cfg.name}: not a pure paged-KV architecture "
                             "(use the dense runtime)")
        self.cfg = cfg
        self.G = lm.n_groups(cfg)
        self.gs = lm.group_size(cfg)
        self.n_layers = self.G * self.gs
        self.page_tokens = page_tokens
        self.max_seq = max_seq
        self.pps = math.ceil(max_seq / page_tokens)
        K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        dtype = jnp.dtype(cfg.compute_dtype)
        self.token_bytes = 2 * K * hd * dtype.itemsize          # per layer
        if local_pages is None:
            # fit `max_running` full-length requests plus the scratch page
            local_pages = max_running * self.n_layers * self.pps + 1
        self.aqua = AquaTensor(n_logical=n_logical,
                               page_shape=(2, K, page_tokens, hd),
                               local_slots=local_pages,
                               host_slots=host_pages, dtype=dtype,
                               meter=meter, name=f"{cfg.name}/kv")
        # pinned LOCAL dummy page: idle batch lanes and block-table padding
        # point here so masked DMAs (and idle-lane appends) stay in-bounds
        self._scratch_lp = int(self.aqua.allocate(1, prefer=LOCAL)[0])
        # rid -> (n_layers, pages) logical page ids, grown as ctx grows
        self._pages: Dict[int, List[List[int]]] = {}

    # -- geometry ---------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Pages per layer covering n_tokens."""
        return max(1, math.ceil(n_tokens / self.page_tokens))

    def pages_per_request(self, n_tokens: int) -> int:
        return self.n_layers * self.pages_for(n_tokens)

    def kv_footprint_bytes(self, n_tokens: int) -> float:
        """Native-dtype whole-stack KV bytes of a request (no page slack)."""
        return float(self.n_layers * n_tokens * self.token_bytes)

    @property
    def page_budget(self) -> int:
        """LOCAL pages available to requests (scratch page excluded)."""
        return self.aqua.local_pool.shape[0] - 1

    @property
    def scratch_slot(self) -> int:
        return int(self.aqua.page_table[self._scratch_lp, 1])

    @property
    def pool(self) -> jnp.ndarray:
        return self.aqua.local_pool

    @pool.setter
    def pool(self, value: jnp.ndarray):
        self.aqua.local_pool = value

    @property
    def meter(self) -> TransferMeter:
        return self.aqua.meter

    # -- allocation -------------------------------------------------------
    def ensure_capacity(self, rid: int, n_tokens: int):
        """Grow the request's per-layer block tables to cover n_tokens.

        New pages must be LOCAL (the kernels read the LOCAL pool): if the
        allocator had to spill a fresh page to another tier the LOCAL pool is
        full and no later step could pull it back either, so fail loudly here
        with the tensor/tier MemoryError. The page-budget-aware schedulers
        are designed to keep planned run sets below this point.
        """
        rows = self._pages.setdefault(rid, [[] for _ in range(self.n_layers)])
        need = self.pages_for(n_tokens)
        for row in rows:
            while len(row) < need:
                lp = int(self.aqua.allocate(1, prefer=LOCAL)[0])
                if self.aqua.page_table[lp, 0] != LOCAL:
                    self.aqua.ensure_local([lp])    # raises: LOCAL exhausted
                row.append(lp)

    def _flat(self, rid: int) -> np.ndarray:
        return np.asarray([lp for row in self._pages[rid] for lp in row],
                          np.int64)

    def release(self, rid: int):
        if rid in self._pages:
            self.aqua.free(self._flat(rid))
            del self._pages[rid]

    # -- block tables (the kernel operands) -------------------------------
    def block_tables_prefill(self, rid: int, pad_to: Optional[int] = None
                             ) -> jnp.ndarray:
        """(G, gs, pad_to) physical LOCAL slots for one request's allocated
        pages from position 0, scratch-padded. Chunked prefill passes a FIXED
        ``pad_to`` (pps plus the write-window spill) so every chunk of every
        request shares one block-table shape — no retrace per context length."""
        rows = self._pages[rid]
        bt = self.aqua.block_tables(rows, pad_to=pad_to or len(rows[0]),
                                    pad_slot=self.scratch_slot)
        return jnp.asarray(bt.reshape(self.G, self.gs, -1))

    def block_tables(self, lane_rids: Sequence[Optional[int]]) -> jnp.ndarray:
        """Batched query: (G, gs, B, pps) physical LOCAL slots, one row per
        batch lane; empty lanes and padding point at the scratch page."""
        B = len(lane_rids)
        rows: List[List[int]] = []
        for l in range(self.n_layers):
            for rid in lane_rids:
                rows.append(self._pages[rid][l] if rid is not None else [])
        bt = self.aqua.block_tables(rows, pad_to=self.pps,
                                    pad_slot=self.scratch_slot)
        return jnp.asarray(bt.reshape(self.G, self.gs, B, self.pps))

    # -- tier migration (preempt / restore as page-table flips) ------------
    def park(self, rid: int, n_tokens: int, *, prefer: int = REMOTE):
        """Preempt: flip the request's pages out of LOCAL — one coalesced
        message per (tier, donor) group, each page metered at its fill.

        ``n_tokens`` is the KV actually RESIDENT in the pool (for an engine
        request at ctx_len that is ctx_len-1: the newest token's K/V is
        appended at its next decode step). A page allocated ahead of a
        boundary but not yet written moves at fill 0.
        """
        for row in self._pages[rid]:
            fills = np.clip(n_tokens - np.arange(len(row)) * self.page_tokens,
                            0, self.page_tokens) / self.page_tokens
            self.aqua.set_page_fill(row, fills)
        self.aqua.offload(self._flat(rid), prefer=prefer)

    def restore(self, rid: int):
        """Make every page of the request LOCAL (no-op when already there)."""
        self.aqua.ensure_local(self._flat(rid))
        for row in self._pages[rid]:
            self.aqua.set_page_fill(row, 1.0)

    def nonlocal_pages(self, rid: int) -> int:
        """Pages of the request currently NOT in the LOCAL tier."""
        rows = self.aqua.page_table[self._flat(rid)]
        return int((rows[:, 0] != LOCAL).sum())

    def can_restore(self, rid: int) -> bool:
        """True when a restore fits the free LOCAL slots right now — the
        prefetch guard: an early ``ensure_local`` must never steal pages the
        current run set still needs (it would raise mid-step otherwise)."""
        return self.nonlocal_pages(rid) <= self.aqua.local_free

    # -- coordinator-driven lease plumbing --------------------------------
    def add_remote_lease(self, donor: str, nbytes: float):
        slots = max(1, int(nbytes // self.aqua.page_bytes))
        self.aqua.add_remote_lease(donor, slots)

    def evict_remote(self, donor: str) -> int:
        return self.aqua.evict_remote(donor)

    def stats(self) -> Dict:
        return {"tiers": self.aqua.tier_counts(),
                "page_tokens": self.page_tokens,
                "meter": {"bytes_fabric": self.aqua.meter.bytes_fabric,
                          "bytes_host": self.aqua.meter.bytes_host,
                          "messages_fabric": self.aqua.meter.messages_fabric,
                          "messages_host": self.aqua.meter.messages_host,
                          "sim_time": self.aqua.meter.sim_time}}


# ===========================================================================
# Legacy blob path — compatibility shim for non-paged families
# ===========================================================================
def _is_seq_leaf(leaf, max_seq: int) -> bool:
    return leaf.ndim >= 3 and leaf.shape[2] == max_seq


def extract_slot(cache, slot: int, ctx_len: int, max_seq: int):
    """[shim] Slice one request's context out of the batched cache pytree."""
    def f(leaf):
        if _is_seq_leaf(leaf, max_seq):
            return leaf[:, slot, :ctx_len]
        return leaf[:, slot]
    return jax.tree.map(f, cache)


def insert_slot(cache, ctx, slot: int, ctx_len: int, max_seq: int):
    """[shim] Write a request's context back into the batched cache."""
    def f(leaf, part):
        if _is_seq_leaf(leaf, max_seq):
            return leaf.at[:, slot, :ctx_len].set(part.astype(leaf.dtype))
        return leaf.at[:, slot].set(part.astype(leaf.dtype))
    return jax.tree.map(f, cache, ctx)


def pack_context(ctx) -> Tuple[jnp.ndarray, List[Tuple[tuple, Any]]]:
    """[shim] Flatten a context pytree into one f32 vector + restore metadata.

    This is the seed blob path the paged runtime replaces: every cache leaf
    is gathered and upcast to float32 on EVERY context switch (a ~2x byte
    blowup for bf16 state) — kept only for families whose decode state is
    not paged KV, and as the benchmark baseline.
    """
    leaves = jax.tree.leaves(ctx)
    meta = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    return flat, meta


def unpack_context(flat: jnp.ndarray, meta, treedef):
    parts = []
    off = 0
    for shape, dtype in meta:
        n = int(np.prod(shape))
        parts.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, parts)


@dataclass
class ParkedContext:
    page_ids: np.ndarray
    n_elems: int
    meta: list
    treedef: Any
    ctx_len: int


class ContextStore:
    """[shim] Pages parked request contexts into an AquaTensor as f32 blobs."""

    def __init__(self, *, page_elems: int = 32768, local_pages: int = 64,
                 host_pages: int = 4096, n_logical: int = 8192,
                 meter: Optional[TransferMeter] = None):
        self.page_elems = page_elems
        self.aqua = AquaTensor(n_logical=n_logical, page_shape=(page_elems,),
                               local_slots=local_pages, host_slots=host_pages,
                               dtype=jnp.float32, meter=meter, name="ctx")

    @property
    def meter(self) -> TransferMeter:
        return self.aqua.meter

    # -- coordinator-driven lease plumbing --------------------------------
    def add_remote_lease(self, donor: str, nbytes: float):
        slots = max(1, int(nbytes // (self.page_elems * 4)))
        self.aqua.add_remote_lease(donor, slots)

    def evict_remote(self, donor: str) -> int:
        return self.aqua.evict_remote(donor)

    # -- park / restore ----------------------------------------------------
    def park(self, ctx, ctx_len: int, *, prefer: int = REMOTE) -> ParkedContext:
        flat, meta = pack_context(ctx)       # the coalescing gather
        treedef = jax.tree.structure(ctx)
        n_pages = math.ceil(flat.size / self.page_elems)
        pad = n_pages * self.page_elems - flat.size
        flat = jnp.pad(flat, (0, pad))
        lps = self.aqua.allocate(n_pages, prefer=prefer)
        self.aqua.write(lps, flat.reshape(n_pages, self.page_elems))
        return ParkedContext(lps, flat.size - pad, meta, treedef, ctx_len)

    def restore(self, parked: ParkedContext):
        pages = self.aqua.read(parked.page_ids, meter=True)
        flat = pages.reshape(-1)[: parked.n_elems]
        ctx = unpack_context(flat, parked.meta, parked.treedef)
        self.aqua.free(parked.page_ids)
        return ctx

    def stats(self) -> Dict:
        return {"tiers": self.aqua.tier_counts(),
                "meter": {"bytes_fabric": self.aqua.meter.bytes_fabric,
                          "bytes_host": self.aqua.meter.bytes_host,
                          "messages_fabric": self.aqua.meter.messages_fabric,
                          "messages_host": self.aqua.meter.messages_host,
                          "sim_time": self.aqua.meter.sim_time}}
