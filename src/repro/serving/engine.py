"""The serving engine: chunked continuous batching with pluggable schedulers
(FCFS / CFS) on the unified paged state runtime.

EVERY family's per-request dynamic context lives on AquaTensor pages
(``PagedStateRuntime``): attention K/V and MLA latents on token-paged
planes, Mamba ssm/conv tails and RWKV6 wkv/shift state on fixed-size state
planes. Decode and prefill read/write the LOCAL pools inside the jit'd
whole-step programs (attention through the ``kernels/paged_attention``
block-table kernels — interpret mode on CPU — MLA and recurrent planes via
shape-stable jnp gathers), and a CFS preemption is a page-table tier flip
for any family — ``offload(pages)`` out, ``ensure_local(pages)`` back, one
coalesced message per (plane, tier, donor) group, zero repacking (paper
§3+§5). There is no dense fallback runtime: the seed-era dense blob-store
shim is deleted. Families with no page plane yet (windowed ring buffers,
attention-logit softcap, encoder-decoder) are rejected at construction.

Prefill is CHUNKED: every step spends at most ``step_tokens`` tokens, split
between the decode lanes and prompt chunks of the run set's pending
prefills (several requests' chunks may ride one step), so no step scales
with the longest prompt. Recurrent planes stay exact across chunk
boundaries (masked identity transitions for the bucket padding). A VLM
prompt's ``prefix_embeds`` occupy its first ``n_prefix`` positions and are
injected into the chunks that cover them (the ``q_start == 0`` side of the
prompt). All paged entry points go through shape buckets — chunk lengths
pad to a power-of-two ladder, block tables and decode lanes to fixed sizes
— so the jit cache holds a constant number of traces regardless of the
prompt-length mix. Page restores for the NEXT step's scheduled requests are
prefetched during the current step and priced with the transfer hidden up
to the step's compute time (``perfmodel.overlapped_transfer_time``).

The engine runs REAL model numerics (any paged-servable family in the zoo)
on tiny configs in CI; its per-step wall-times are additionally priced by
core/perfmodel.py so end-to-end TTFT/RCT in *simulated seconds* are reported
for the benchmark harness. The scheduler and paging logic are shared with the
discrete-event simulator — one implementation, two clocks.

Coordinator integration (consumer side): at engine construction, AQUA-LIB
requests offloaded memory (/allocate); every ``respond_every`` iterations the
engine polls pending reclaims (the paper's ``aqua.respond()``) and evacuates
donor pools at the iteration boundary.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import REMOTE
from repro.core.coordinator import Coordinator
from repro.core.perfmodel import (HardwareProfile, ModelCost, TPU_V5E,
                                  overlapped_transfer_time)
from repro.models import api
from repro.serving.kv_cache import PagedStateRuntime
from repro.serving.scheduler import (CFSScheduler, Decision, FCFSScheduler,
                                     ReqState, bucket_tokens, fairness_spread,
                                     split_step_budget)


class SchedulingInvariantError(RuntimeError):
    """The planned run set violated an engine invariant (e.g. more requests
    than free batch slots) — a scheduler bug that must fail loudly instead of
    silently skipping placement and serving the request never."""


@dataclass
class EngineMetrics:
    sim_time: float = 0.0
    steps: int = 0
    prefills: int = 0                     # prefill chunk executions
    preemptions: int = 0
    restores: int = 0
    prefetched_restores: int = 0          # restores overlapped with compute
    overlap_hidden_s: float = 0.0         # transfer time hidden by overlap
    ttft: Dict[int, float] = field(default_factory=dict)
    rct: Dict[int, float] = field(default_factory=dict)
    fairness_trace: List[int] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    prefill_tokens_trace: List[int] = field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_running: int = 4,
                 max_seq: int = 128, scheduler: str = "cfs",
                 slice_tokens: int = 4, offload_tier: int = REMOTE,
                 kv: Optional[PagedStateRuntime] = None,
                 kv_page_tokens: int = 8,
                 kv_local_pages: Optional[int] = None,
                 kv_host_pages: int = 8192,
                 prefix_sharing: bool = True,
                 paged_impl: str = "pallas",
                 step_tokens: Optional[int] = None,
                 prefetch: bool = True,
                 coordinator: Optional[Coordinator] = None,
                 name: str = "llm0", hw: HardwareProfile = TPU_V5E,
                 want_remote_bytes: float = 0.0, respond_every: int = 4):
        """Build a serving engine on the unified paged state runtime.

        Args:
            cfg: model config (must be paged-servable) and ``params`` its
                weights pytree.
            max_running: batch slots (concurrent decode lanes).
            max_seq: maximum context length per request.
            scheduler: ``"cfs"`` (fair, preempting) or ``"fcfs"``.
            slice_tokens: CFS fair-pick period in generated tokens.
            offload_tier: preferred park tier (``REMOTE`` fabric / ``HOST``).
            kv: an existing :class:`PagedStateRuntime` to serve on; by
                default one is built from the ``kv_*`` sizing knobs.
            prefix_sharing: enable copy-on-write prompt-prefix sharing
                (effective only on all-token-plane families).
            paged_impl: ``"pallas"`` kernels (interpret on CPU) or the
                ``"xla"`` jnp oracles.
            step_tokens: per-step token budget for chunked prefill
                (``None`` = whole-prompt chunks); must be >= 8.
            prefetch: overlap next-step page restores with compute.
            coordinator/want_remote_bytes/respond_every: AQUA-LIB consumer
                wiring — lease donor HBM at construction, poll reclaims
                every ``respond_every`` steps.
            name: engine id used in coordinator bookkeeping and errors.
            hw: hardware profile pricing the simulated clock.

        Raises:
            ValueError: the family is not paged-servable, or
                ``step_tokens < 8``.
        """
        self.cfg = cfg
        self.params = params
        self.max_running = max_running
        self.max_seq = max_seq
        self.name = name
        self.hw = hw
        self.cost = ModelCost.from_config(cfg)
        self.weight_bytes = cfg.param_count() * cfg.dtype().itemsize
        self.offload_tier = offload_tier
        self.paged_impl = paged_impl

        if not api.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name}: not paged-servable — windowed ring-buffer / "
                "softcap / encoder-decoder layers have no page plane yet "
                "(ROADMAP follow-up); the dense blob runtime is gone")

        if step_tokens is not None and step_tokens < 8:
            raise ValueError("step_tokens must be >= 8 (one chunk bucket)")
        self.step_tokens = step_tokens
        self.prefetch = prefetch

        self.kv = kv or PagedStateRuntime(
            cfg, max_seq=max_seq, page_tokens=kv_page_tokens,
            local_pages=kv_local_pages, host_pages=kv_host_pages,
            max_running=max_running, prefix_sharing=prefix_sharing)
        self.pager = self.kv
        # the scheduler plans in PAGES (a per-plane cost vector). CFS
        # revisits the run set every slice, so it budgets one slice of
        # growth; FCFS never preempts, so an admitted request must fit the
        # LOCAL pools to COMPLETION.
        page_cost = (self._page_cost_cfs if scheduler == "cfs"
                     else self._page_cost_fcfs)
        page_budget = self.kv.page_budget
        # chunk block tables pad to the request's max pages PLUS the write
        # window of the largest chunk bucket: ONE table shape for every
        # (chunk, context-length) combination
        hi = bucket_tokens(max_seq)
        self._pps_pad = (self.kv.pps
                         + math.ceil(hi / self.kv.page_tokens) + 1)

        self.coord = coordinator
        self.respond_every = respond_every
        if coordinator is not None and want_remote_bytes > 0:
            for donor, nbytes in coordinator.allocate(name, want_remote_bytes):
                self.pager.add_remote_lease(donor, nbytes)
                self._grants = getattr(self, "_grants", []) + [(donor, nbytes)]

        self.slice_tokens = slice_tokens
        self._free_slots = list(range(max_running))[::-1]
        self.sched = (CFSScheduler(max_running, slice_tokens,
                                   page_cost=page_cost,
                                   page_budget=page_budget)
                      if scheduler == "cfs"
                      else FCFSScheduler(max_running, page_cost=page_cost,
                                         page_budget=page_budget))
        self.waiting: List[ReqState] = []
        self.running: List[ReqState] = []
        self.finished: List[ReqState] = []
        self._prefetched: List[ReqState] = []
        self.metrics = EngineMetrics()
        self._rid = itertools.count()

    def _shared_discount(self, r: ReqState,
                         chosen: Sequence[ReqState]) -> np.ndarray:
        """PHYSICAL pages this request aliases with the run set chosen so
        far (counted once by the sharer already picked), minus the headroom
        a pending copy-on-write recompute may claim back."""
        if not self.kv.sharing or not chosen:
            return np.zeros(len(self.kv.planes), np.int64)
        disc = self.kv.shared_pages_with(
            r.rid, [o.rid for o in chosen if o.rid != r.rid])
        if r.shared_tokens and r.prefill_pos < r.shared_tokens:
            # the final-position recompute of a fully-matched prompt CoWs
            # the tail shared page in every layer row of each token plane
            disc = np.maximum(disc - self.kv.cow_reserve(), 0)
        return disc

    def _page_cost_cfs(self, r: ReqState,
                       chosen: Sequence[ReqState] = ()) -> np.ndarray:
        """Per-plane PHYSICAL pages the request needs LOCAL through the next
        slice boundary: context now plus one slice of growth (CFS re-plans
        every slice), minus pages shared with the run set chosen so far —
        shared prefixes directly raise admission capacity."""
        base = self.kv.pages_per_request(
            min(r.ctx_len + self.slice_tokens, self.max_seq))
        return base - self._shared_discount(r, chosen)

    def _page_cost_fcfs(self, r: ReqState,
                        chosen: Sequence[ReqState] = ()) -> np.ndarray:
        """FCFS never preempts: an admitted request holds LOCAL pages until
        it completes, so budget its full remaining generation (minus pages
        shared with already-admitted sharers, which stay allocated for as
        long as any referencer lives)."""
        remaining = r.max_new_tokens - len(r.generated)
        base = self.kv.pages_per_request(
            min(r.ctx_len + max(remaining, 0), self.max_seq))
        return base - self._shared_discount(r, chosen)

    # ------------------------------------------------------------------
    def submit(self, prompt_tokens: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0, lora_id: Optional[int] = None,
               prefix_embeds=None) -> ReqState:
        """Queue a request for generation.

        If prefix sharing is enabled (the default on all-token-plane
        families) the prompt is matched against the runtime's prefix index
        here: the longest page-aligned prefix another live request already
        wrote is ADOPTED — the new request's block tables alias those
        physical pages (refcounted, copy-on-write) and its chunked prefill
        starts past the shared prefix (``ReqState.shared_tokens``,
        ``prefill_pos``). At least the final prompt position is always
        recomputed so the first-token logits exist.

        Args:
            prompt_tokens: prompt token ids (ints).
            max_new_tokens: tokens to generate before the request retires.
            arrival: arrival timestamp on the simulated clock (TTFT/RCT are
                reported relative to it).
            lora_id: adapter id; partitions the prefix index (the same
                tokens under a different adapter never alias).
            prefix_embeds: for a VLM config (``cfg.n_prefix_embeds > 0``)
                the (n_prefix, d) / (1, n_prefix, d) patch-embedding block
                occupying the prompt's first positions; omitted, it defaults
                to zeros (the stub frontend's null image). VLM requests
                never share prefixes (the image is not in the hash).

        Returns:
            The queued :class:`ReqState` (its ``generated`` list fills in
            as the engine steps).

        Raises:
            ValueError: ``prefix_embeds`` passed to a non-VLM config.
        """
        r = ReqState(next(self._rid), arrival, list(map(int, prompt_tokens)),
                     max_new_tokens, lora_id=lora_id)
        if self.cfg.n_prefix_embeds:
            P, d = self.cfg.n_prefix_embeds, self.cfg.d_model
            if prefix_embeds is None:
                prefix_embeds = jnp.zeros((1, P, d), self.cfg.dtype())
            prefix_embeds = jnp.asarray(prefix_embeds).reshape(1, P, d)
            r.n_prefix = P
            r.prefix_embeds = prefix_embeds
        elif prefix_embeds is not None:
            raise ValueError(f"{self.cfg.name} takes no prefix embeds")
        if self.kv.sharing and not r.n_prefix:
            shared = self.kv.adopt_prefix(r.rid, r.prompt_tokens,
                                          seed=lora_id)
            if shared:
                r.shared_tokens = shared
                # always leave >= 1 position to compute: the last chunk
                # produces the first-token logits (a full match recomputes
                # the final position, CoW-cloning the tail shared page)
                r.prefill_pos = min(shared, r.prompt_positions - 1)
        self.waiting.append(r)
        return r

    # ------------------------------------------------------------------
    def _respond(self):
        """The paper's aqua.respond(): honor donor reclaims at an iteration
        boundary — evacuate their pools and release the grants."""
        for donor in self.coord.pending_reclaims(self.name):
            self.pager.evict_remote(donor)
            for d, nbytes in list(getattr(self, "_grants", [])):
                if d == donor:
                    self.coord.free(self.name, donor, nbytes)
                    self._grants.remove((d, nbytes))

    # ------------------------------------------------------------------
    def step(self):
        """Run ONE engine step: plan the run set, execute the plan, decode.

        In order: (1) poll coordinator reclaims every ``respond_every``
        steps; (2) ``sched.plan`` picks the run set under the physical-page
        budget; (3) ``_place`` parks preempted requests (page-table tier
        flips), slots + restores scheduled ones, and runs this step's
        prompt chunks under the ``step_tokens`` budget; (4) one decode token
        for every resident prefilled request; (5) finished requests retire
        (pages released — shared prefix pages survive while any sharer
        lives); (6) next step's restores are prefetched, priced as hidden
        up to this step's compute time. Metrics (TTFT/RCT on the simulated
        clock, step times, fairness spread) accrue on ``self.metrics``.

        Raises:
            SchedulingInvariantError: the planned run set needs more batch
                slots than exist — a scheduler bug, never silent.
            MemoryError: a page allocation or tier flip found every slot of
                the target tier full (the page-budget-aware schedulers are
                designed to keep plans below this point).
        """
        m = self.metrics
        if self.coord is not None and m.steps % self.respond_every == 0:
            self._respond()

        decision = self.sched.plan(m.steps, self.waiting, self.running)

        # the step's token budget: one token per decode lane, the remainder
        # handed out as prompt chunks (several requests' chunks per step)
        lanes = [r for r in decision.run if r.prefilled and not r.done]
        pending = [r for r in decision.run if not r.prefilled]
        chunks = split_step_budget(
            self.step_tokens, len(lanes),
            [r.prompt_positions - r.prefill_pos for r in pending])

        compute_time, transfer_time = self._place(decision,
                                                  list(zip(pending, chunks)))

        self.running = [r for r in decision.run if r.slot is not None]
        self.waiting = [r for r in self.waiting + decision.preempt
                        if r.slot is None and not r.done]

        # one decode step for every resident request past its prefill
        live = [r for r in self.running if not r.done and r.prefilled]
        if live:
            compute_time += self._decode(live)
        step_time = compute_time + transfer_time

        # retire bookkeeping first: freed slots/pages raise the odds the
        # prefetch below fits (times are stamped after the prefetch)
        retired = []
        for r in list(self.running):
            if r.done:
                r.finish_step = m.steps
                self._free_slots.append(r.slot)
                r.slot = None
                r.prefix_embeds = None       # don't pin VLM embeds forever
                self.kv.release(r.rid)
                self.running.remove(r)
                self.finished.append(r)
                retired.append(r)

        step_time += self._prefetch_restores(compute_time)

        # TTFT: one accounting for prefill- and decode-produced first tokens —
        # the time the step COMPLETES, including everything accrued in it
        # (the visible excess of a prefetched restore included)
        for r in self.running + retired:
            if r.generated and r.rid not in m.ttft:
                r.ttft_step = m.steps
                m.ttft[r.rid] = m.sim_time + step_time - r.arrival
        for r in retired:
            m.rct[r.rid] = m.sim_time + step_time - r.arrival

        m.sim_time += step_time
        m.steps += 1
        m.step_times.append(step_time)
        m.fairness_trace.append(
            fairness_spread(self.waiting + self.running))

    # ------------------------------------------------------------------
    # placement: park preempted requests, slot + restore the scheduled set,
    # run this step's prefill chunks
    # ------------------------------------------------------------------
    def _place(self, decision: Decision,
               chunk_plan: List) -> tuple:
        """Execute a plan. Returns ``(prefill_compute_time,
        metered_transfer_time)``."""
        m = self.metrics
        t_before = self.pager.meter.sim_time
        if self._prefetched:
            # prefetch misprediction (a submit() between steps changed the
            # plan): re-park so LOCAL holds only the planned run set — the
            # page-budget invariant ensure_capacity relies on
            run_ids = {r.rid for r in decision.run}
            for r in self._prefetched:
                if (r.parked is None and r.slot is None and not r.done
                        and r.rid not in run_ids):
                    self.kv.park(r.rid, r.resident_tokens,
                                 prefer=self.offload_tier)
                    r.parked = True
            self._prefetched = []
        for r in decision.preempt:
            # only r.resident_tokens of context exist in the pools: the
            # newest generated token's state lands at its next decode step
            self.kv.park(r.rid, r.resident_tokens, prefer=self.offload_tier)
            r.parked = True
            self._free_slots.append(r.slot)
            r.slot = None
            m.preemptions += 1
        for r in decision.run:
            if r.slot is not None:
                continue
            if not self._free_slots:
                raise SchedulingInvariantError(
                    f"{self.name}: planned run set needs a slot for request "
                    f"{r.rid} but none are free (max_running="
                    f"{self.max_running}) — scheduler exceeded the slot cap")
            r.slot = self._free_slots.pop()
            if r.parked:
                self.kv.restore(r.rid)       # ensure_local: coalesced page-in
                r.parked = None
                m.restores += 1
        prefill_time = 0.0
        ptoks = 0
        for r, n in chunk_plan:
            if n <= 0 or r.slot is None:
                continue
            prefill_time += self._prefill_chunk(r, n)
            ptoks += n
            m.prefills += 1
        m.prefill_tokens_trace.append(ptoks)
        return prefill_time, self.pager.meter.sim_time - t_before

    # ------------------------------------------------------------------
    # prefetch: restore next step's scheduled requests DURING this step,
    # pricing the transfer as hidden up to the step's compute time
    # ------------------------------------------------------------------
    def _prefetch_restores(self, compute_time: float) -> float:
        if not self.prefetch or not (self.waiting or self.running):
            return 0.0
        m = self.metrics
        nxt = self.sched.peek(m.steps + 1, self.waiting, self.running)
        t_before = self.pager.meter.sim_time
        for r in nxt.run:
            if r.parked and self.kv.can_restore(r.rid):
                self.kv.restore(r.rid)
                r.parked = None
                m.restores += 1
                m.prefetched_restores += 1
                self._prefetched.append(r)
        transfer = self.pager.meter.sim_time - t_before
        if transfer <= 0.0:
            return 0.0
        visible = overlapped_transfer_time(compute_time, transfer)
        m.overlap_hidden_s += transfer - visible
        return visible

    # ------------------------------------------------------------------
    # runtime primitives
    # ------------------------------------------------------------------
    def _prefill_chunk(self, r: ReqState, n_tokens: int) -> float:
        """Run one prompt chunk: allocate its pages, write every plane's
        state in place, produce the first token when the chunk completes the
        prompt. ``n_tokens`` counts prompt POSITIONS — a VLM request's first
        chunks cover its prefix-embedding rows, whose token ids are dummies
        and whose residual rows come from ``prefix_embeds`` instead."""
        start = r.prefill_pos
        self.kv.ensure_capacity(r.rid, start + n_tokens)
        # copy-on-write: a fully-matched prompt recomputes its final
        # position INTO the shared tail page — clone it first
        self.kv.make_writable(r.rid, start, start + n_tokens)
        Tb = bucket_tokens(n_tokens)         # shape bucket, not exact length
        toks = np.zeros((1, Tb), np.int32)
        idx = np.arange(n_tokens) + start - r.n_prefix
        text = idx >= 0
        toks[0, :n_tokens][text] = np.asarray(r.prompt_tokens,
                                              np.int32)[idx[text]]
        bt = self.kv.block_tables_prefill(r.rid, pad_to=self._pps_pad)
        logits, self.kv.pools = api.prefill_chunk_paged(
            self.params, self.cfg, jnp.asarray(toks), self.kv.pools, bt,
            jnp.int32(start), jnp.int32(n_tokens - 1),
            prefix_embeds=r.prefix_embeds,
            read_pps=self.kv.pps, impl=self.paged_impl)
        r.prefill_pos = start + n_tokens
        if not r.n_prefix:
            # publish completed full prompt pages into the prefix index so
            # later arrivals with the same prefix adopt them
            self.kv.register_prefix(r.rid, r.prefill_pos)
        if r.prefilled:
            r.generated.append(int(jnp.argmax(logits[0])))
        return self.cost.prefill_time(self.hw, n_tokens)

    def _decode(self, live: List[ReqState]) -> float:
        tokens = np.zeros((self.max_running,), np.int32)
        pos = np.zeros((self.max_running,), np.int32)
        lanes: List[Optional[int]] = [None] * self.max_running
        for r in live:
            # the new token's position may cross into a fresh page: grow the
            # block tables (allocation guarantees LOCAL; parked requests
            # were already restored in _place). A decode append landing in
            # a still-shared page copies it first (CoW).
            self.kv.ensure_capacity(r.rid, r.ctx_len)
            self.kv.make_writable(r.rid, r.ctx_len - 1, r.ctx_len)
            lanes[r.slot] = r.rid
            tokens[r.slot] = (r.generated[-1] if r.generated
                              else r.prompt_tokens[-1])
            pos[r.slot] = r.ctx_len - 1
        bts = self.kv.block_tables(lanes)
        logits, self.kv.pools = api.decode_step_paged(
            self.params, self.cfg, self.kv.pools, bts,
            jnp.asarray(tokens), jnp.asarray(pos), impl=self.paged_impl)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        ctx_mean = float(np.mean([r.ctx_len for r in live]))
        for r in live:
            r.generated.append(int(nxt[r.slot]))
        return self.cost.decode_step_time(self.hw, len(live), ctx_mean,
                                          self.weight_bytes)

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 1000):
        """Step until every submitted request finished (or ``max_steps``);
        honors pending coordinator reclaims before returning. Returns the
        engine's :class:`EngineMetrics`."""
        for _ in range(max_steps):
            if not (self.waiting or self.running):
                break
            self.step()
        if self.coord is not None:
            self._respond()        # don't leave leases dangling after drain
        return self.metrics
