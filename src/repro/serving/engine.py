"""The serving engine: continuous batching over a slotted decode cache, with
pluggable schedulers (FCFS / CFS) and AQUA-paged context switching.

This engine runs REAL model numerics (any decoder-only family in the zoo) on
tiny configs in CI; its per-step wall-times are additionally priced by
core/perfmodel.py so end-to-end TTFT/RCT in *simulated seconds* are reported
for the benchmark harness. The scheduler and paging logic are shared with the
discrete-event simulator — one implementation, two clocks.

Coordinator integration (consumer side): at engine construction, AQUA-LIB
requests offloaded memory (/allocate); every ``respond_every`` iterations the
engine polls pending reclaims (the paper's ``aqua.respond()``) and evacuates
donor pools at the iteration boundary.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import HOST, REMOTE, TransferMeter
from repro.core.coordinator import Coordinator
from repro.core.perfmodel import (HardwareProfile, ModelCost, TPU_V5E)
from repro.models import api
from repro.serving.kv_cache import ContextStore, extract_slot, insert_slot
from repro.serving.scheduler import (CFSScheduler, Decision, FCFSScheduler,
                                     ReqState, fairness_spread)


@dataclass
class EngineMetrics:
    sim_time: float = 0.0
    steps: int = 0
    prefills: int = 0
    preemptions: int = 0
    restores: int = 0
    ttft: Dict[int, float] = field(default_factory=dict)
    rct: Dict[int, float] = field(default_factory=dict)
    fairness_trace: List[int] = field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_running: int = 4,
                 max_seq: int = 128, scheduler: str = "cfs",
                 slice_tokens: int = 4, offload_tier: int = REMOTE,
                 store: Optional[ContextStore] = None,
                 coordinator: Optional[Coordinator] = None,
                 name: str = "llm0", hw: HardwareProfile = TPU_V5E,
                 want_remote_bytes: float = 0.0, respond_every: int = 4):
        self.cfg = cfg
        self.params = params
        self.max_running = max_running
        self.max_seq = max_seq
        self.name = name
        self.hw = hw
        self.cost = ModelCost.from_config(cfg)
        self.weight_bytes = cfg.param_count() * cfg.dtype().itemsize
        self.offload_tier = offload_tier
        self.store = store or ContextStore(page_elems=4096, local_pages=16,
                                           host_pages=1024)
        self.coord = coordinator
        self.respond_every = respond_every
        if coordinator is not None and want_remote_bytes > 0:
            for donor, nbytes in coordinator.allocate(name, want_remote_bytes):
                self.store.add_remote_lease(donor, nbytes)
                self._grants = getattr(self, "_grants", []) + [(donor, nbytes)]

        self.cache = api.init_decode_state(cfg, max_running, max_seq)
        self._free_slots = list(range(max_running))[::-1]
        self.sched = (CFSScheduler(max_running, slice_tokens)
                      if scheduler == "cfs" else FCFSScheduler(max_running))
        self.waiting: List[ReqState] = []
        self.running: List[ReqState] = []
        self.finished: List[ReqState] = []
        self.metrics = EngineMetrics()
        self._rid = itertools.count()

    # ------------------------------------------------------------------
    def submit(self, prompt_tokens: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0, lora_id: Optional[int] = None) -> ReqState:
        r = ReqState(next(self._rid), arrival, list(map(int, prompt_tokens)),
                     max_new_tokens, lora_id=lora_id)
        self.waiting.append(r)
        return r

    # ------------------------------------------------------------------
    def _respond(self):
        """The paper's aqua.respond(): honor donor reclaims at an iteration
        boundary — evacuate their pools and release the grants."""
        for donor in self.coord.pending_reclaims(self.name):
            self.store.evict_remote(donor)
            for d, nbytes in list(getattr(self, "_grants", [])):
                if d == donor:
                    self.coord.free(self.name, donor, nbytes)
                    self._grants.remove((d, nbytes))

    def step(self):
        m = self.metrics
        step_time = 0.0
        if self.coord is not None and m.steps % self.respond_every == 0:
            self._respond()

        decision = self.sched.plan(m.steps, self.waiting, self.running)

        # page out preempted requests (coalesced blob -> AQUA tensor)
        t_before = self.store.aqua.meter.sim_time
        for r in decision.preempt:
            ctx = extract_slot(self.cache, r.slot, r.ctx_len, self.max_seq)
            r.parked = self.store.park(ctx, r.ctx_len, prefer=self.offload_tier)
            self._free_slots.append(r.slot)
            r.slot = None
            m.preemptions += 1

        # restore / prefill the scheduled set
        for r in decision.run:
            if r.slot is not None:
                continue
            if not self._free_slots:
                continue                     # shouldn't happen: plan respects cap
            r.slot = self._free_slots.pop()
            if r.parked is not None:
                ctx = self.store.restore(r.parked)
                self.cache = insert_slot(self.cache, ctx, r.slot, r.ctx_len,
                                         self.max_seq)
                r.parked = None
                m.restores += 1
            elif not r.prefilled:
                step_time += self._prefill_into_slot(r)
                m.prefills += 1
        step_time += self.store.aqua.meter.sim_time - t_before

        self.running = [r for r in decision.run if r.slot is not None]
        self.waiting = [r for r in self.waiting + decision.preempt
                        if r.slot is None and not r.done]

        # one decode step for every resident request
        live = [r for r in self.running if not r.done]
        if live:
            tokens = np.zeros((self.max_running,), np.int32)
            pos = np.zeros((self.max_running,), np.int32)
            for r in live:
                tokens[r.slot] = (r.generated[-1] if r.generated
                                  else r.prompt_tokens[-1])
                pos[r.slot] = r.ctx_len - 1
            logits, self.cache = api.decode_step(
                self.params, self.cfg, self.cache,
                jnp.asarray(tokens), jnp.asarray(pos))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            ctx_mean = float(np.mean([r.ctx_len for r in live]))
            step_time += self.cost.decode_step_time(
                self.hw, len(live), ctx_mean, self.weight_bytes)
            for r in live:
                r.generated.append(int(nxt[r.slot]))
                if r.ttft_step is None:
                    r.ttft_step = m.steps
                    m.ttft[r.rid] = m.sim_time + step_time - r.arrival

        # retire
        for r in list(self.running):
            if r.done:
                r.finish_step = m.steps
                m.rct[r.rid] = m.sim_time + step_time - r.arrival
                self._free_slots.append(r.slot)
                r.slot = None
                self.running.remove(r)
                self.finished.append(r)

        m.sim_time += step_time
        m.steps += 1
        m.fairness_trace.append(
            fairness_spread(self.waiting + self.running))

    def _prefill_into_slot(self, r: ReqState) -> float:
        cache1 = api.init_decode_state(self.cfg, 1, self.max_seq)
        toks = jnp.asarray(r.prompt_tokens, jnp.int32)[None]
        logits, cache1 = api.prefill(self.params, self.cfg, toks, cache1)
        self.cache = jax.tree.map(
            lambda big, one: big.at[:, r.slot].set(one[:, 0].astype(big.dtype)),
            self.cache, cache1)
        r.prefilled = True
        r.generated.append(int(jnp.argmax(logits[0])))
        if r.ttft_step is None:
            r.ttft_step = self.metrics.steps
            self.metrics.ttft[r.rid] = self.metrics.sim_time - r.arrival
        return self.cost.prefill_time(self.hw, len(r.prompt_tokens))

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 1000):
        for _ in range(max_steps):
            if not (self.waiting or self.running):
                break
            self.step()
        if self.coord is not None:
            self._respond()        # don't leave leases dangling after drain
        return self.metrics
