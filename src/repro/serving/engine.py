"""The serving engine: chunked continuous batching with pluggable schedulers
(FCFS / CFS) on the unified paged state runtime.

EVERY family's per-request dynamic context lives on AquaTensor pages
(``PagedStateRuntime``): attention K/V and MLA latents on token-paged
planes, Mamba ssm/conv tails and RWKV6 wkv/shift state on fixed-size state
planes. Decode and prefill read/write the LOCAL pools inside the jit'd
whole-step programs (attention through the ``kernels/paged_attention``
block-table kernels — interpret mode on CPU — MLA and recurrent planes via
shape-stable jnp gathers), and a CFS preemption is a page-table tier flip
for any family — ``offload(pages)`` out, ``ensure_local(pages)`` back, one
coalesced message per (plane, tier, donor) group, zero repacking (paper
§3+§5). There is no dense fallback runtime: the seed-era dense blob-store
shim is deleted. Families with no page plane yet (windowed ring buffers,
attention-logit softcap, encoder-decoder) are rejected at construction.

Prefill is CHUNKED: every step spends at most ``step_tokens`` tokens, split
between the decode lanes and prompt chunks of the run set's pending
prefills (several requests' chunks may ride one step), so no step scales
with the longest prompt. Recurrent planes stay exact across chunk
boundaries (masked identity transitions for the bucket padding). A VLM
prompt's ``prefix_embeds`` occupy its first ``n_prefix`` positions and are
injected into the chunks that cover them (the ``q_start == 0`` side of the
prompt).

The whole step is ONE JITTED CALL (``api.serve_step_paged``): every decode
lane and every scheduled prompt chunk is packed into a single (rows x
chunk-bucket) token batch with per-row ``(q_start, n_real, is_decode)``
metadata, and each layer serves all rows in one fused mixed-mode attention
launch. The per-request chunk loop and the separate decode call are GONE
from the engine — dispatch overhead per step is O(1) in the number of
admitted requests instead of O(requests) (the between-launch idle regime
of Kossmann et al. 2024), priced by ``perfmodel.launch_overhead_time``.
Row logits are bit-identical to the per-request entry points the packed
rows replace. With decode lanes present, the chunk budget is additionally
capped by the launch's memory-bound FLOPs slack
(``ModelCost.piggyback_tokens``) so mixed steps stay AT the roofline. When
``split_step_budget`` leaves slack (every admitted prefill fully granted),
WAITING prefills get it as speculative chunks riding the same call — in
arrival order, PAST the head-of-line waiter while page headroom allows —
each parked again right after, so admission finds their prompts partially
prefilled.

All paged entry points go through shape buckets — chunk lengths and packed
row counts pad to power-of-two ladders, block tables and decode lanes to
fixed sizes — so the jit cache holds a constant number of traces
regardless of the prompt-length mix or the number of admitted requests.
Page restores for the NEXT step's scheduled requests are prefetched during
the current step and priced with the transfer hidden up to the step's
compute time (``perfmodel.overlapped_transfer_time``).

The engine runs REAL model numerics (any paged-servable family in the zoo)
on tiny configs in CI; its per-step wall-times are additionally priced by
core/perfmodel.py so end-to-end TTFT/RCT in *simulated seconds* are reported
for the benchmark harness. The scheduler and paging logic are shared with the
discrete-event simulator — one implementation, two clocks.

Coordinator integration (consumer side): at engine construction, AQUA-LIB
requests offloaded memory (/allocate); every ``respond_every`` iterations the
engine polls pending reclaims (the paper's ``aqua.respond()``) and evacuates
donor pools at the iteration boundary.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, fields as dataclass_fields
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import HOST, REMOTE
from repro.core.coordinator import Coordinator
# re-exported for backward compatibility: SchedulingInvariantError predates
# the typed hierarchy in core/errors.py and callers import it from here
from repro.core.errors import (CancelledError, EngineCrashError,
                               SchedulingInvariantError)  # noqa: F401
from repro.core.faults import InvariantAuditor
from repro.core.perfmodel import (HardwareProfile, ModelCost, TPU_V5E,
                                  overlapped_transfer_time)
from repro.models import api
from repro.serving.kv_cache import PagedStateRuntime
from repro.serving.scheduler import (CFSScheduler, Decision, FCFSScheduler,
                                     ReqState, bucket_tokens, fairness_spread,
                                     split_step_budget)


@dataclass
class EngineMetrics:
    sim_time: float = 0.0
    steps: int = 0
    prefills: int = 0                     # prefill chunk rows executed
    preemptions: int = 0
    restores: int = 0
    prefetched_restores: int = 0          # restores overlapped with compute
    overlap_hidden_s: float = 0.0         # transfer time hidden by overlap
    spec_chunks: int = 0                  # speculative chunk-ahead grants
    spec_tokens: int = 0                  # tokens prefilled speculatively
    # speculative tier flips ride OUTSIDE preemptions/restores: each spec
    # chunk parks once after running (spec_chunks parks) and pages its
    # prior speculated prefix back in first (spec_restores); the admission
    # restore of a spec-parked request still counts in `restores`. The
    # preemptions == restores symmetry therefore only holds when
    # speculation never fired (spec_chunks == 0).
    spec_restores: int = 0
    ttft: Dict[int, float] = field(default_factory=dict)
    rct: Dict[int, float] = field(default_factory=dict)
    fairness_trace: List[int] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    prefill_tokens_trace: List[int] = field(default_factory=list)
    # kernel launches per step: fused (what the engine issues — one call,
    # ~n_layers launches) vs the per-request baseline it replaced (one call
    # per chunk row + one for decode, each ~n_layers launches)
    launch_trace: List[int] = field(default_factory=list)
    baseline_launch_trace: List[int] = field(default_factory=list)
    # fault-tolerance accounting (zero on a fault-free run): transfer-leg
    # retries absorbed by bounded backoff, donor losses / lease shrinks
    # applied, pages live-migrated off shrinking donors, and the requests
    # whose pages died with a donor and were recomputed from the prompt
    leg_retries: int = 0
    donor_losses: int = 0
    lease_shrinks: int = 0
    migrated_pages: int = 0
    recomputes: int = 0
    recovered_rids: List[int] = field(default_factory=list)
    # burst/admission observability: waiting-queue depth at each plan, the
    # run+waiting set's occupied fraction of the page budget (max over
    # planes, marginal under prefix sharing), and cumulative defer
    # decisions by the SLO-aware admission controller (0 with admission
    # off — arrivals go straight to the scheduler)
    queue_depth_trace: List[int] = field(default_factory=list)
    occupancy_trace: List[float] = field(default_factory=list)
    admission_deferrals: int = 0
    # request-lifecycle accounting: submissions, teardowns before
    # completion (client cancels + deadline expiries + fault cancels),
    # the deadline-expiry subset, requests parked by a graceful drain,
    # and no-progress watchdog escalations into the recovery ladder
    submitted: int = 0
    cancelled: int = 0
    deadline_missed: int = 0
    drained: int = 0
    watchdog_trips: int = 0

    def ttft_quantile(self, q: float, *, censored: int = 0) -> float:
        """TTFT quantile on the simulated clock (nan when nothing finished
        a first token yet) — p50/p99 reporting for the burst benchmarks.

        ``censored`` makes right-censoring EXPLICIT instead of silently
        excluded: that many submitted-but-never-first-token requests
        (cancelled, expired, still queued at measurement time) are counted
        as +inf observations, so a quantile landing in the censored tail
        returns ``inf`` — the honest answer when e.g. p99 asks about a
        request that never got a first token. The engine's own count is
        ``metrics.submitted - len(metrics.ttft)``. The default (0)
        preserves the historical finished-only quantile."""
        xs = sorted(self.ttft.values())
        n = len(xs) + max(int(censored), 0)
        if n == 0:
            return float("nan")
        i = min(int(q * n), n - 1)
        return float(xs[i]) if i < len(xs) else float("inf")


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_running: int = 4,
                 max_seq: int = 128, scheduler: str = "cfs",
                 slice_tokens: int = 4, offload_tier: int = REMOTE,
                 kv: Optional[PagedStateRuntime] = None,
                 kv_page_tokens: int = 8,
                 kv_local_pages: Optional[int] = None,
                 kv_host_pages: int = 8192,
                 prefix_sharing: bool = True,
                 prefix_cache: bool = True,
                 paged_impl: str = "pallas",
                 step_tokens: Optional[int] = None,
                 prefetch: bool = True,
                 spec_chunk_ahead: bool = True,
                 coordinator: Optional[Coordinator] = None,
                 name: str = "llm0", hw: HardwareProfile = TPU_V5E,
                 want_remote_bytes: float = 0.0, respond_every: int = 4,
                 mesh=None, faults=None, audit: bool = False,
                 admission: bool = False, admission_headroom: float = 0.9,
                 prefill_admit_limit: Optional[int] = 4,
                 slo_ttft_s: Optional[float] = None,
                 watchdog_steps: Optional[int] = None):
        """Build a serving engine on the unified paged state runtime.

        Args:
            cfg: model config (must be paged-servable) and ``params`` its
                weights pytree.
            max_running: batch slots (concurrent decode lanes).
            max_seq: maximum context length per request.
            scheduler: ``"cfs"`` (fair, preempting) or ``"fcfs"``.
            slice_tokens: CFS fair-pick period in generated tokens.
            offload_tier: preferred park tier (``REMOTE`` fabric / ``HOST``).
            kv: an existing :class:`PagedStateRuntime` to serve on; by
                default one is built from the ``kv_*`` sizing knobs.
            prefix_sharing: enable copy-on-write prompt-prefix sharing
                (effective only on all-token-plane families).
            prefix_cache: retain refcount-0 prefix pages in the radix
                index as a global prefix cache (evicted cold-first under
                page pressure); effective only with ``prefix_sharing``.
            paged_impl: ``"pallas"`` kernels (interpret on CPU) or the
                ``"xla"`` jnp oracles.
            step_tokens: per-step token budget for chunked prefill
                (``None`` = whole-prompt chunks); must be >= 8.
            prefetch: overlap next-step page restores with compute.
            spec_chunk_ahead: when the step's token budget has slack after
                every admitted prefill is fully granted, speculatively
                prefill WAITING requests' next chunks — arrival order,
                extending past the head-of-line waiter while page headroom
                allows (each grant page-headroom guarded, parked right
                after) — instead of idling the slack. Effective only with
                a ``step_tokens`` budget.
            coordinator/want_remote_bytes/respond_every: AQUA-LIB consumer
                wiring — lease donor HBM at construction, poll reclaims
                every ``respond_every`` steps.
            name: engine id used in coordinator bookkeeping and errors.
            hw: hardware profile pricing the simulated clock.
            mesh: optional ``MeshTierDomain`` — REMOTE parks become real
                collective page moves to peer-device donor slabs, and
                :meth:`calibrate_clock` can refit ``hw``'s fabric link to
                the measured transfer times. Ignored when ``kv`` is given
                (the runtime's own mesh wins).
            faults: optional ``core/faults.FaultInjector`` — attached to
                every plane and the mesh so transfer legs and lease
                boundaries consult it; its step-scheduled ``FaultEvent``\\s
                (donor loss, lease shrink) are applied at the top of each
                engine step, with live migration / recompute-from-prompt
                recovery and scheduler budget re-planning.
            admission: layer the SLO-aware admission controller
                (``serving/admission.py``) ahead of the scheduler — waiting
                requests enter the scheduler's view only while the
                committed set's projected KV-occupancy trajectory (each
                request priced at its marginal per-plane page cost, growing
                to its terminal context) stays inside
                ``admission_headroom`` x the page budget; everything else
                defers in the queue (never rejected). Composes with
                ``_replan_capacity``: a lease shrink or donor loss
                contracts the stability region the next step.
            admission_headroom: fraction of the page budget the projected
                trajectory may fill (the rest absorbs projection error).
            prefill_admit_limit: with admission on, max requests in their
                prefill phase at once while decode lanes are live
                (prefill/decode priority mixing; ``None`` = uncapped).
            slo_ttft_s: optional TTFT SLO in simulated seconds — admissions
                whose projected prefill completion misses it are counted
                (``admission.slo_at_risk``), observational only.
            watchdog_steps: flag any RESIDENT request whose combined
                prefill+decode progress hasn't advanced for this many
                steps (a starved prefill behind a saturated decode batch,
                a fault-wedged restore) and escalate it through the
                recovery ladder's recompute rung (``_recover_lost``:
                release, requeue, recompute) so the slot it wedged comes
                back. ``None`` (default) disables the watchdog.
            audit: run a full ``InvariantAuditor`` pass after EVERY step
                (refcounts vs block tables vs tier occupancy vs meter and
                collective counters) — a debug mode that fails loudly on
                state corruption instead of letting it surface as wrong
                logits later.

        Raises:
            ValueError: the family is not paged-servable, or
                ``step_tokens < 8``.
        """
        self.cfg = cfg
        self.params = params
        self.max_running = max_running
        self.max_seq = max_seq
        self.name = name
        self.hw = hw
        self.cost = ModelCost.from_config(cfg)
        self.weight_bytes = cfg.param_count() * cfg.dtype().itemsize
        self.offload_tier = offload_tier
        self.paged_impl = paged_impl

        if not api.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name}: not paged-servable — windowed ring-buffer / "
                "softcap / encoder-decoder layers have no page plane yet "
                "(ROADMAP follow-up); the dense blob runtime is gone")

        if step_tokens is not None and step_tokens < 8:
            raise ValueError("step_tokens must be >= 8 (one chunk bucket)")
        self.step_tokens = step_tokens
        self.prefetch = prefetch
        self.spec_chunk_ahead = spec_chunk_ahead

        self.kv = kv or PagedStateRuntime(
            cfg, max_seq=max_seq, page_tokens=kv_page_tokens,
            local_pages=kv_local_pages, host_pages=kv_host_pages,
            max_running=max_running, prefix_sharing=prefix_sharing,
            prefix_cache=prefix_cache, mesh=mesh)
        self.pager = self.kv
        # the scheduler plans in PAGES (a per-plane cost vector). CFS
        # revisits the run set every slice, so it budgets one slice of
        # growth; FCFS never preempts, so an admitted request must fit the
        # LOCAL pools to COMPLETION.
        page_cost = (self._page_cost_cfs if scheduler == "cfs"
                     else self._page_cost_fcfs)
        page_budget = self.kv.page_budget
        # chunk block tables pad to the request's max pages PLUS the write
        # window of the largest chunk bucket: ONE table shape for every
        # (chunk, context-length) combination
        hi = bucket_tokens(max_seq)
        self._pps_pad = (self.kv.pps
                         + math.ceil(hi / self.kv.page_tokens) + 1)

        self.coord = coordinator
        self.respond_every = respond_every
        if coordinator is not None and want_remote_bytes > 0:
            for donor, nbytes in coordinator.allocate(name, want_remote_bytes):
                self.pager.add_remote_lease(donor, nbytes)
                self._grants = getattr(self, "_grants", []) + [(donor, nbytes)]

        self.slice_tokens = slice_tokens
        self._free_slots = list(range(max_running))[::-1]
        # prefix-aware co-scheduling: requests adopting the same root-edge
        # radix node cluster behind their group's earliest member within a
        # vruntime class, so a shared prefix parks/restores once per plan
        prefix_group = ((lambda r: self.kv.prefix_group_of(r.rid))
                        if self.kv.sharing else None)
        self.sched = (CFSScheduler(max_running, slice_tokens,
                                   page_cost=page_cost,
                                   page_budget=page_budget,
                                   prefix_group=prefix_group)
                      if scheduler == "cfs"
                      else FCFSScheduler(max_running, page_cost=page_cost,
                                         page_budget=page_budget))
        self.waiting: List[ReqState] = []
        self.running: List[ReqState] = []
        self.finished: List[ReqState] = []
        self._prefetched: List[ReqState] = []
        self.metrics = EngineMetrics()
        self._next_rid = 0
        # request-lifecycle state: drain gate, watchdog progress marks
        self.watchdog_steps = watchdog_steps
        self._draining = False
        self._watch: Dict[int, tuple] = {}
        # constructor knobs a crash-consistent snapshot must carry so
        # `restore` can rebuild an equivalently-sized engine. The
        # local-pages knob only sizes TOKEN planes (state-plane pools
        # derive from max_running), so read it back off one of those.
        tok_plane = next((p for p in self.kv.planes.values()
                          if p.kind == "tokens"), None)
        first_plane = next(iter(self.kv.planes.values()))
        self._snap_knobs = dict(
            max_running=max_running, max_seq=max_seq, scheduler=scheduler,
            slice_tokens=slice_tokens, offload_tier=offload_tier,
            kv_page_tokens=self.kv.page_tokens,
            kv_local_pages=(int(tok_plane.aqua.local_pool.shape[0])
                            if tok_plane is not None else None),
            kv_host_pages=int(first_plane.aqua.host_pool.shape[0]),
            prefix_sharing=self.kv.sharing,
            prefix_cache=bool(getattr(self.kv, "caching", False)),
            paged_impl=paged_impl, step_tokens=step_tokens,
            prefetch=prefetch, spec_chunk_ahead=spec_chunk_ahead,
            name=name, admission=admission,
            admission_headroom=admission_headroom,
            prefill_admit_limit=prefill_admit_limit,
            slo_ttft_s=slo_ttft_s, watchdog_steps=watchdog_steps)

        self.faults = faults
        if faults is not None:
            self.kv.attach_faults(faults)
        self.auditor = InvariantAuditor() if audit else None

        # SLO-aware admission: a one-way gate AHEAD of the scheduler. The
        # budget is read through the scheduler each step, so a fault
        # event's _replan_capacity contracts the stability region with no
        # extra wiring; costs are the schedulers' own marginal per-plane
        # page vectors plus the FCFS-style terminal footprint.
        self.admission = None
        self._eligible_rids: Optional[set] = None
        if admission:
            from repro.serving.admission import AdmissionController
            self.admission = AdmissionController(
                budget=lambda: np.asarray(self.sched.page_budget,
                                          np.float64),
                current_cost=self._page_cost_now,
                terminal_cost=self._page_cost_fcfs,
                remaining_tokens=lambda r: (
                    r.prompt_positions - r.prefill_pos,
                    r.max_new_tokens - len(r.generated)),
                headroom=admission_headroom,
                step_tokens=self.step_tokens,
                prefill_admit_limit=prefill_admit_limit,
                slo_ttft_s=slo_ttft_s,
                step_time=lambda: self.cost.decode_step_time(
                    self.hw, max(len(self.running), 1), self.max_seq / 2,
                    self.weight_bytes),
                # earliest-deadline-first candidate order: urgency, not
                # just age, decides who prices against the region first —
                # deadline-free requests keep their arrival order after
                # every deadline-carrying one
                order_key=lambda r: (
                    (r.arrival + r.deadline_s)
                    if getattr(r, "deadline_s", None) is not None
                    else float("inf"), r.arrival, r.rid),
                # remaining e2e slack — a candidate whose projected finish
                # exceeds it is excluded from the occupancy trajectory
                # (work that will miss anyway must not crowd out work that
                # can still make it)
                deadline_of=lambda r: (
                    None if getattr(r, "deadline_s", None) is None
                    else r.deadline_s - (self.metrics.sim_time - r.arrival)))

    def _shared_discount(self, r: ReqState,
                         chosen: Sequence[ReqState]) -> np.ndarray:
        """PHYSICAL pages this request aliases with the run set chosen so
        far (counted once by the sharer already picked), minus the headroom
        a pending copy-on-write recompute may claim back."""
        if not self.kv.sharing or not chosen:
            return np.zeros(len(self.kv.planes), np.int64)
        disc = self.kv.shared_pages_with(
            r.rid, [o.rid for o in chosen if o.rid != r.rid])
        if r.shared_tokens and r.prefill_pos < r.shared_tokens:
            # the final-position recompute of a fully-matched prompt CoWs
            # the tail shared page in every layer row of each token plane
            disc = np.maximum(disc - self.kv.cow_reserve(), 0)
        return disc

    def _page_cost_cfs(self, r: ReqState,
                       chosen: Sequence[ReqState] = ()) -> np.ndarray:
        """Per-plane PHYSICAL pages the request needs LOCAL through the next
        slice boundary: context now plus one slice of growth (CFS re-plans
        every slice), minus pages shared with the run set chosen so far —
        shared prefixes directly raise admission capacity."""
        base = self.kv.pages_per_request(
            min(r.ctx_len + self.slice_tokens, self.max_seq))
        return base - self._shared_discount(r, chosen)

    def _page_cost_now(self, r: ReqState,
                       chosen: Sequence[ReqState] = ()) -> np.ndarray:
        """Per-plane PHYSICAL pages the request occupies RIGHT NOW (no
        growth term), marginal against ``chosen`` — the admission
        controller's trajectory starting point and the occupancy metric."""
        base = self.kv.pages_per_request(min(r.ctx_len, self.max_seq))
        return base - self._shared_discount(r, chosen)

    def _occupancy_frac(self) -> float:
        """Occupied fraction of the per-plane page budget by the running
        set (max over planes, shared prefixes counted once)."""
        budget = np.maximum(np.asarray(self.sched.page_budget, np.float64),
                            1.0)
        pages = np.zeros(len(self.kv.planes), np.float64)
        chosen: List[ReqState] = []
        for r in self.running:
            pages = pages + self._page_cost_now(r, chosen)
            chosen.append(r)
        return float(np.max(pages / budget))

    def _page_cost_fcfs(self, r: ReqState,
                        chosen: Sequence[ReqState] = ()) -> np.ndarray:
        """FCFS never preempts: an admitted request holds LOCAL pages until
        it completes, so budget its full remaining generation (minus pages
        shared with already-admitted sharers, which stay allocated for as
        long as any referencer lives)."""
        remaining = r.max_new_tokens - len(r.generated)
        base = self.kv.pages_per_request(
            min(r.ctx_len + max(remaining, 0), self.max_seq))
        return base - self._shared_discount(r, chosen)

    # ------------------------------------------------------------------
    def submit(self, prompt_tokens: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0, lora_id: Optional[int] = None,
               prefix_embeds=None, deadline_s: Optional[float] = None,
               ttft_deadline_s: Optional[float] = None) -> ReqState:
        """Queue a request for generation.

        If prefix sharing is enabled (the default on all-token-plane
        families) the prompt is matched against the runtime's prefix index
        here: the longest page-aligned prefix another live request already
        wrote is ADOPTED — the new request's block tables alias those
        physical pages (refcounted, copy-on-write) and its chunked prefill
        starts past the shared prefix (``ReqState.shared_tokens``,
        ``prefill_pos``). At least the final prompt position is always
        recomputed so the first-token logits exist.

        Args:
            prompt_tokens: prompt token ids (ints).
            max_new_tokens: tokens to generate before the request retires.
            arrival: arrival timestamp on the simulated clock (TTFT/RCT are
                reported relative to it).
            lora_id: adapter id; partitions the prefix index (the same
                tokens under a different adapter never alias).
            prefix_embeds: for a VLM config (``cfg.n_prefix_embeds > 0``)
                the (n_prefix, d) / (1, n_prefix, d) patch-embedding block
                occupying the prompt's first positions; omitted, it defaults
                to zeros (the stub frontend's null image). VLM requests
                never share prefixes (the image is not in the hash).
            deadline_s: end-to-end deadline in simulated seconds AFTER
                ``arrival``; once exceeded, the per-step deadline sweep
                cancels the request (terminal state ``"expired"``) and
                reclaims its pages the same step. With admission on, the
                controller also orders candidates earliest-deadline-first
                and excludes projected-to-miss work from its occupancy
                trajectory.
            ttft_deadline_s: first-token deadline on the same base —
                enforced only until the first token lands.

        Returns:
            The queued :class:`ReqState` (its ``generated`` list fills in
            as the engine steps).

        Raises:
            ValueError: ``prefix_embeds`` passed to a non-VLM config.
        """
        r = ReqState(self._next_rid, arrival, list(map(int, prompt_tokens)),
                     max_new_tokens, lora_id=lora_id,
                     deadline_s=deadline_s, ttft_deadline_s=ttft_deadline_s)
        self._next_rid += 1
        self.metrics.submitted += 1
        if self.cfg.n_prefix_embeds:
            P, d = self.cfg.n_prefix_embeds, self.cfg.d_model
            if prefix_embeds is None:
                prefix_embeds = jnp.zeros((1, P, d), self.cfg.dtype())
            prefix_embeds = jnp.asarray(prefix_embeds).reshape(1, P, d)
            r.n_prefix = P
            r.prefix_embeds = prefix_embeds
        elif prefix_embeds is not None:
            raise ValueError(f"{self.cfg.name} takes no prefix embeds")
        if self.kv.sharing and not r.n_prefix:
            shared = self.kv.adopt_prefix(r.rid, r.prompt_tokens,
                                          seed=lora_id)
            if shared:
                r.shared_tokens = shared
                # always leave >= 1 position to compute: the last chunk
                # produces the first-token logits (a full match recomputes
                # the final position, CoW-cloning the tail shared page)
                r.prefill_pos = min(shared, r.prompt_positions - 1)
        self.waiting.append(r)
        return r

    # ------------------------------------------------------------------
    def _respond(self):
        """The paper's aqua.respond(): honor donor reclaims at an iteration
        boundary — evacuate their pools and release the grants."""
        reclaimed = False
        for donor in self.coord.pending_reclaims(self.name):
            self.pager.evict_remote(donor)
            reclaimed = True
            for d, nbytes in list(getattr(self, "_grants", [])):
                if d == donor:
                    self.coord.free(self.name, donor, nbytes)
                    self._grants.remove((d, nbytes))
        if reclaimed:
            self._replan_capacity()

    # ------------------------------------------------------------------
    # lifecycle transition helpers — the ONLY places engine bookkeeping
    # state (batch slots, page ownership, the finished list) may change.
    # Every exit path (finish ladder, cancel, deadline expiry, lost-page
    # recovery, preemption) goes through these, and a CI grep-guard pins
    # each mutation pattern to exactly one occurrence in this file.
    # ------------------------------------------------------------------
    def _free_slot(self, r: ReqState) -> None:
        """Return a request's batch slot to the pool (no-op if slotless)."""
        if r.slot is not None:
            self._free_slots.append(r.slot)
            r.slot = None

    def _release_pages(self, r: ReqState) -> None:
        """Release every plane page a request holds, defensively clearing
        any prefetched restore first: ``_prefetch_restores`` may have
        restored (and pinned) this rid's pages for the NEXT plan in the
        same step it finishes or cancels — the release drops the pin via
        the active set either way, and the stale ``_prefetched`` entry
        must not re-park a retired rid at the next ``_place``."""
        self._prefetched = [p for p in self._prefetched if p.rid != r.rid]
        self.kv.release(r.rid)

    def _retire(self, r: ReqState, terminal: str,
                reason: Optional[str] = None) -> None:
        """The one lifecycle exit: free the slot, release the pages, stamp
        the terminal state (``finished`` / ``cancelled`` / ``expired``) and
        move the request to ``finished``. The caller removes it from
        ``running``/``waiting`` first."""
        m = self.metrics
        self._free_slot(r)
        self._release_pages(r)
        r.parked = None
        r.prefix_embeds = None           # don't pin VLM embeds forever
        r.terminal = terminal
        r.cancel_reason = reason
        r.finish_step = m.steps
        self.finished.append(r)
        self._watch.pop(r.rid, None)
        if self.admission is not None:
            self.admission.forget(r.rid)

    # ------------------------------------------------------------------
    # cancellation, deadlines, drain, watchdog
    # ------------------------------------------------------------------
    def cancel(self, rid: int, *, reason: str = "client") -> bool:
        """Tear a request out of ANY lifecycle state — waiting, prefilling
        mid-chunk, decoding, parked, mid-prefetch, or speculated — and
        reclaim everything it holds, within the current step.

        Mirrors the finish ladder exactly (slot back to the pool, every
        plane page released through refcounts, prefetched restores
        un-pinned, ``admission.forget``), with one addition: the completed
        page-aligned prompt prefix is PUBLISHED into the radix index
        before teardown, so with the prefix cache on the prefill work
        already done is retained for future sharers instead of freed.

        ``reason`` is recorded on the request (``"client"``, ``"deadline"``,
        ``"fault"``); a ``"deadline"`` cancel stamps the ``"expired"``
        terminal state. Idempotent: returns False when ``rid`` is unknown
        or already retired, True when the request was torn down. Callers
        that need the tokens-so-far read them off the returned
        :class:`ReqState` in ``finished``; :meth:`output` raises the typed
        :class:`~repro.core.errors.CancelledError` for them."""
        r = next((x for x in self.running + self.waiting if x.rid == rid),
                 None)
        if r is None:
            return False
        if self.kv.sharing and not r.n_prefix:
            # salvage before teardown: cache-publish the full prompt blocks
            # this request already prefilled (release then free_to_caches
            # them instead of dropping the work)
            self.kv.register_prefix(r.rid, r.prefill_pos)
        if r in self.running:
            self.running.remove(r)
        else:
            self.waiting.remove(r)
        self._retire(r, "expired" if reason == "deadline" else "cancelled",
                     reason=reason)
        self.metrics.cancelled += 1
        return True

    def output(self, rid: int) -> List[int]:
        """Generated tokens of a RETIRED request — the client result path.

        Raises:
            CancelledError: the request was cancelled or expired (the
                typed signal carries ``rid`` and the recorded reason).
            ValueError: ``rid`` is unknown or still in flight.
        """
        r = next((x for x in self.finished if x.rid == rid), None)
        if r is None:
            raise ValueError(f"request {rid} is unknown or still in flight")
        if r.terminal in ("cancelled", "expired"):
            raise CancelledError(
                f"request {rid} was {r.terminal} "
                f"({r.cancel_reason or 'no reason recorded'})",
                rid=rid, reason=r.cancel_reason)
        return list(r.generated)

    def _shed_expired(self) -> None:
        """Enforce both deadline clocks at the top of the step, BEFORE the
        admission gate sees the queue: an expired waiter is shed before it
        can be admitted, an expired runner is cancelled and its pages
        reclaimed the same step. TTFT deadlines only bind until the first
        token landed."""
        m = self.metrics
        for r in list(self.waiting) + list(self.running):
            age = m.sim_time - r.arrival
            ttft_miss = (r.ttft_deadline_s is not None
                         and r.rid not in m.ttft
                         and age > r.ttft_deadline_s)
            e2e_miss = r.deadline_s is not None and age > r.deadline_s
            if (ttft_miss or e2e_miss) \
                    and self.cancel(r.rid, reason="deadline"):
                m.deadline_missed += 1

    def _watchdog(self) -> None:
        """Flag resident requests making NO prefill+decode progress for
        ``watchdog_steps`` consecutive steps — a prefill starved to
        zero-token chunks behind a saturated decode batch holds its slot
        and pages indefinitely — and escalate through the recovery
        ladder's recompute rung (:meth:`_recover_lost`): pages released,
        request requeued, context recomputed bit-identically on its next
        admission. The lower rungs (bounded leg retry, live migration)
        already ran inside the data plane; a request still stuck after
        them has nothing left to wait for."""
        m = self.metrics
        for r in list(self.running):
            prog = r.prefill_pos + len(r.generated)
            last, since = self._watch.get(r.rid, (None, m.steps))
            if prog != last:
                self._watch[r.rid] = (prog, m.steps)
            elif m.steps - since >= self.watchdog_steps:
                m.watchdog_trips += 1
                self._watch.pop(r.rid, None)
                self._recover_lost(r.rid)

    def drain(self) -> int:
        """Graceful drain: stop admitting work and park every restorable
        request to HOST, returning (synchronously) once the engine is
        quiescent — no batch slot held, no active pins, no in-flight
        prefetch. Queued requests stay queued; in-flight ones keep their
        progress parked on the host tier and resume bit-identically after
        :meth:`resume` (park/restore round-trips are exact). While
        draining, ``step()`` admits nothing, speculates nothing and
        prefetches nothing. Returns the number of requests parked; the
        ``drained`` metric accrues it.

        A drained engine is also the cheapest snapshot point — every
        payload already sits on the slow tier — though :meth:`snapshot`
        works mid-stream too."""
        m = self.metrics
        self._draining = True
        n = 0
        for r in list(self.running):
            self.kv.park(r.rid, r.resident_tokens, prefer=HOST)
            r.parked = True
            self._free_slot(r)
            self.running.remove(r)
            self.waiting.append(r)
            n += 1
        self._prefetched = []
        for r in self.waiting:
            # prefetched restores / speculated chunks left pages active
            if r.rid in self.kv._active:
                self.kv.park(r.rid, r.resident_tokens, prefer=HOST)
                r.parked = True
                n += 1
        m.drained += n
        return n

    def resume(self) -> None:
        """Reopen admission after :meth:`drain`; the next plan restores
        the parked set through the normal placement path."""
        self._draining = False

    # ------------------------------------------------------------------
    # fault application and recovery
    # ------------------------------------------------------------------
    def _replan_capacity(self):
        """Contract the scheduler's admission budget after tiers shrink.

        The planning budget stays the LOCAL pool sizes (the run set must fit
        LOCAL), additionally capped by the runtime's TOTAL live capacity —
        after a lease shrink or donor loss the tiers backing preemption may
        hold fewer pages than LOCAL itself, and admitting up to the LOCAL
        budget would wedge the first park."""
        self.sched.update_budget(
            np.minimum(self.kv.page_budget, self.kv.total_capacity()))

    def _recover_lost(self, rid: int):
        """Degrade-to-host recovery for a request whose pages died with a
        donor: release every surviving page, reset the request to the start
        of prefill, and re-queue it — the greedy decode loop regenerates
        bit-identical tokens from the prompt. A still-resident shared
        prefix (other sharers' pages survived LOCAL/HOST) is re-adopted so
        the recompute starts past it, not from position zero."""
        m = self.metrics
        r = next((x for x in self.running + self.waiting if x.rid == rid),
                 None)
        if r is None or r.done:
            return
        self._free_slot(r)
        if r in self.running:
            self.running.remove(r)
        self._release_pages(r)
        r.parked = None
        r.prefill_pos = 0
        r.generated = []
        r.shared_tokens = 0
        if self.kv.sharing and not r.n_prefix:
            shared = self.kv.adopt_prefix(r.rid, r.prompt_tokens,
                                          seed=r.lora_id)
            if shared:
                r.shared_tokens = shared
                r.prefill_pos = min(shared, r.prompt_positions - 1)
        if r not in self.waiting:
            self.waiting.append(r)
        if self.admission is not None:
            # the victim resets to prefill position 0 AND the stability
            # region just contracted — it must re-price before re-entry
            self.admission.forget(rid)
        m.recomputes += 1
        m.recovered_rids.append(rid)

    def _apply_faults(self) -> float:
        """Apply the injector's scheduled fault events, then re-plan
        admission capacity. The poll is DUAL-CLOCK — ``at_step`` events
        fire on the engine's step counter, ``at_time`` events on its
        simulated clock — so one schedule (e.g. ``make_cancel_events``)
        drives the engine and the byte-clock simulator alike. A
        ``lease_shrink`` live-migrates the reclaimed slots' pages to
        surviving donors or the host tier; a ``donor_loss`` flips the
        donor's pages to LOST and sends every victim request through
        :meth:`_recover_lost`; a ``cancel`` tears the named request down
        through :meth:`cancel`; an ``engine_crash`` raises
        :class:`~repro.core.errors.EngineCrashError` — the harness
        discards this engine and rebuilds from the latest
        :meth:`snapshot` via :meth:`restore`. Returns the metered
        transfer time the recovery work cost (migration page moves)."""
        m = self.metrics
        t_before = self.pager.meter.sim_time
        fired = False
        for ev in self.faults.due_events(step=m.steps, now=m.sim_time):
            if ev.kind == "engine_crash":
                raise EngineCrashError(
                    f"{self.name}: seeded engine_crash fired at step "
                    f"{m.steps} — rebuild from the latest snapshot "
                    "(ServingEngine.restore)")
            if ev.kind == "cancel":
                if ev.rid is not None:
                    self.cancel(int(ev.rid), reason="fault")
                continue
            fired = True
            if ev.kind == "lease_shrink":
                m.lease_shrinks += 1
                m.migrated_pages += self.kv.shrink_lease(ev.donor, ev.frac)
            elif ev.kind == "donor_loss":
                m.donor_losses += 1
                for rid in self.kv.fail_donor(ev.donor):
                    self._recover_lost(rid)
        if fired:
            self._replan_capacity()
        return self.pager.meter.sim_time - t_before

    # ------------------------------------------------------------------
    def calibrate_clock(self, *, min_samples: int = 4) -> bool:
        """Refit the analytic clock against MEASURED mesh transfers.

        On a mesh-backed runtime every warm collective leg was wall-clocked
        (``MeshTierDomain.samples``); this fits the latency+bandwidth link
        model to those samples (``perfmodel.calibrate_profile``) and swaps
        the calibrated profile into both pricing paths — ``self.hw`` (step
        compute / page-flip times) and the runtime's ``TransferMeter`` — so
        every simulator and benchmark number downstream inherits real
        fabric costs. Returns True when the clock actually changed (False
        without a mesh or with too few samples to fit)."""
        dom = getattr(self.kv, "mesh", None)
        if dom is None:
            return False
        hw2 = dom.calibrated_profile(self.hw, min_samples=min_samples)
        if hw2 is self.hw:
            return False
        self.hw = hw2
        self.pager.meter.hw = hw2
        return True

    # ------------------------------------------------------------------
    def step(self):
        """Run ONE engine step: plan the run set, execute the plan as a
        single fused call.

        In order: (1) poll coordinator reclaims every ``respond_every``
        steps; (2) ``sched.plan`` picks the run set under the physical-page
        budget; (3) ``_place`` parks preempted requests (page-table tier
        flips) and slots + restores scheduled ones; (4) the WHOLE step's
        work — one decode token per resident prefilled request plus every
        pending prefill's fair-share chunk under the ``step_tokens`` budget
        (plus speculative chunks for waiting prefills when the budget has
        slack) — is packed into ONE ``api.serve_step_paged``
        call; (5) finished requests retire (pages released — shared prefix
        pages survive while any sharer lives); (6) next step's restores are
        prefetched, priced as hidden up to this step's compute time.
        Metrics (TTFT/RCT on the simulated clock, step times, launches per
        step, fairness spread) accrue on ``self.metrics``.

        Raises:
            SchedulingInvariantError: the planned run set needs more batch
                slots than exist — a scheduler bug, never silent.
            MemoryError: a page allocation or tier flip found every slot of
                the target tier full (the page-budget-aware schedulers are
                designed to keep plans below this point).
        """
        m = self.metrics
        if self.coord is not None and m.steps % self.respond_every == 0:
            self._respond()
        fault_time = (self._apply_faults() if self.faults is not None
                      else 0.0)
        self._shed_expired()

        # admission gate: the scheduler only ever sees the eligible subset
        # of the queue — deferred requests stay waiting (degrade-to-queue)
        # until completions reopen the stability region. While draining,
        # NOTHING is eligible: the queue holds until resume().
        m.queue_depth_trace.append(len(self.waiting))
        if self._draining:
            eligible = []
            self._eligible_rids = set()
        elif self.admission is not None:
            eligible, deferred = self.admission.filter(self.waiting,
                                                       self.running)
            m.admission_deferrals += len(deferred)
            self._eligible_rids = {r.rid for r in eligible}
        else:
            eligible = self.waiting
            self._eligible_rids = None
        m.occupancy_trace.append(self._occupancy_frac())

        decision = self.sched.plan(m.steps, eligible, self.running)

        # the step's token budget: one token per decode lane, the remainder
        # handed out as prompt chunks (several requests' chunks per step).
        # With decode lanes present the chunk budget is additionally capped
        # by the launch's memory-bound FLOPs slack (the roofline piggyback
        # window): chunk tokens beyond it stop riding the decode stream for
        # free and extend the step linearly.
        lanes = [r for r in decision.run if r.prefilled and not r.done]
        pending = [r for r in decision.run if not r.prefilled]
        flops_slack = None
        if self.step_tokens is not None and lanes:
            ctx_mean = float(np.mean([r.ctx_len for r in lanes]))
            flops_slack = self.cost.piggyback_tokens(
                self.hw, len(lanes), ctx_mean, self.weight_bytes)
        chunks = split_step_budget(
            self.step_tokens, len(lanes),
            [r.prompt_positions - r.prefill_pos for r in pending],
            flops_slack=flops_slack)

        transfer_time = self._place(decision)

        self.running = [r for r in decision.run if r.slot is not None]
        self.waiting = [r for r in self.waiting + decision.preempt
                        if r.slot is None and not r.done]

        # all the step's model work — decode lanes + prompt chunks (+ a
        # speculative chunk-ahead when the budget has slack) — in ONE call
        live = [r for r in self.running if not r.done and r.prefilled]
        chunk_plan = [(r, n) for r, n in zip(pending, chunks)
                      if n > 0 and r.slot is not None]
        specs = self._pick_speculative(decision, len(lanes), chunks,
                                       len(chunk_plan), flops_slack)
        compute_time, fused_transfer = self._fused_step(live, chunk_plan,
                                                        specs)
        step_time = compute_time + transfer_time + fused_transfer + fault_time

        # retire bookkeeping first: freed slots/pages raise the odds the
        # prefetch below fits (times are stamped after the prefetch)
        retired = []
        for r in list(self.running):
            if r.done:
                self.running.remove(r)
                self._retire(r, "finished")
                retired.append(r)

        if self.watchdog_steps is not None:
            self._watchdog()

        step_time += self._prefetch_restores(compute_time)

        # TTFT: one accounting for prefill- and decode-produced first tokens —
        # the time the step COMPLETES, including everything accrued in it
        # (the visible excess of a prefetched restore included)
        for r in self.running + retired:
            if r.generated and r.rid not in m.ttft:
                r.ttft_step = m.steps
                m.ttft[r.rid] = m.sim_time + step_time - r.arrival
        for r in retired:
            m.rct[r.rid] = m.sim_time + step_time - r.arrival

        m.sim_time += step_time
        m.steps += 1
        m.step_times.append(step_time)
        m.fairness_trace.append(
            fairness_spread(self.waiting + self.running))
        m.leg_retries = (self.pager.meter.retries_fabric
                         + self.pager.meter.retries_host)
        if self.auditor is not None:
            self.auditor.audit(self.kv, engine=self)

    # ------------------------------------------------------------------
    # placement: park preempted requests, slot + restore the scheduled set
    # ------------------------------------------------------------------
    def _place(self, decision: Decision) -> float:
        """Execute a plan's page-table moves (park the preempted, slot and
        restore the scheduled). Returns the metered transfer time."""
        m = self.metrics
        t_before = self.pager.meter.sim_time
        if self._prefetched:
            # prefetch misprediction (a submit() between steps changed the
            # plan): re-park so LOCAL holds only the planned run set — the
            # page-budget invariant ensure_capacity relies on
            run_ids = {r.rid for r in decision.run}
            for r in self._prefetched:
                if (r.parked is None and r.slot is None and not r.done
                        and r.rid not in run_ids):
                    self.kv.park(r.rid, r.resident_tokens,
                                 prefer=self.offload_tier)
                    r.parked = True
            self._prefetched = []
        for r in decision.preempt:
            # only r.resident_tokens of context exist in the pools: the
            # newest generated token's state lands at its next decode step
            self.kv.park(r.rid, r.resident_tokens, prefer=self.offload_tier)
            r.parked = True
            self._free_slot(r)
            m.preemptions += 1
        for r in decision.run:
            if r.slot is not None:
                continue
            if not self._free_slots:
                raise SchedulingInvariantError(
                    f"{self.name}: planned run set needs a slot for request "
                    f"{r.rid} but none are free (max_running="
                    f"{self.max_running}) — scheduler exceeded the slot cap")
            r.slot = self._free_slots.pop()
            if r.parked:
                self.kv.restore(r.rid)       # ensure_local: coalesced page-in
                r.parked = None
                m.restores += 1
        return self.pager.meter.sim_time - t_before

    # ------------------------------------------------------------------
    # prefetch: restore next step's scheduled requests DURING this step,
    # pricing the transfer as hidden up to the step's compute time
    # ------------------------------------------------------------------
    def _prefetch_restores(self, compute_time: float) -> float:
        if not self.prefetch or not (self.waiting or self.running):
            return 0.0
        m = self.metrics
        # under admission control, prefetch only what the controller would
        # let the next plan see — restoring a deferred request's pages
        # would pull unadmitted work LOCAL
        pool = (self.waiting if self._eligible_rids is None
                else [r for r in self.waiting
                      if r.rid in self._eligible_rids])
        nxt = self.sched.peek(m.steps + 1, pool, self.running)
        t_before = self.pager.meter.sim_time
        for r in nxt.run:
            if r.parked and self.kv.can_restore(r.rid):
                self.kv.restore(r.rid)
                r.parked = None
                m.restores += 1
                m.prefetched_restores += 1
                self._prefetched.append(r)
        transfer = self.pager.meter.sim_time - t_before
        if transfer <= 0.0:
            return 0.0
        visible = overlapped_transfer_time(compute_time, transfer)
        m.overlap_hidden_s += transfer - visible
        return visible

    # ------------------------------------------------------------------
    # the fused step: ALL model work in one jitted call
    # ------------------------------------------------------------------
    def _pick_speculative(self, decision: Decision, n_lanes: int,
                          chunks: List[int], n_chunk_rows: int = 0,
                          flops_slack: Optional[int] = None) -> List:
        """Speculative chunk-ahead: when ``split_step_budget`` left slack
        (every admitted prefill fully granted this step), hand it to
        WAITING prefills — arrival order, PAST the head-of-line waiter
        while slack and page headroom allow — as extra chunks riding the
        same fused call. Each grant is capped at ``remaining - 1``
        positions (the final position — and the first token — stays for
        admission), must be worth at least one page (a sub-page grant
        would pay the chunk's park/restore flips for almost no prefill
        progress), skips requests preempted THIS step (re-restoring them
        immediately would turn the optimization into pure tier-flip
        thrash), and is page-headroom guarded: the whole speculative
        context must fit the free LOCAL slots of every plane, net of
        earlier grants. The slack is also capped by the decode launch's
        FLOPs piggyback window (``flops_slack``) and the fixed packed row
        budget (specs never widen the fused call's row bucket). Returns a
        list of ``(request, n_tokens)`` grants, possibly empty.

        The headroom check is advisory — the run set's own same-step
        growth (fresh decode pages, CoW clones) allocates first, so
        ``_fused_step`` still treats every speculative allocation as
        fallible and drops the row (and the grants after it) on
        ``MemoryError``."""
        if not self.spec_chunk_ahead or self.step_tokens is None:
            return []
        slack = self.step_tokens - n_lanes - sum(chunks)
        if flops_slack is not None:
            slack = min(slack, max(int(flops_slack) - sum(chunks), 0))
        if slack < self.kv.page_tokens:
            return []
        max_rows = bucket_tokens(self.max_running + 1, lo=1) - n_chunk_rows
        skip = {r.rid for r in decision.run}
        skip.update(r.rid for r in decision.preempt)
        cands = sorted((r for r in self.waiting
                        if r.rid not in skip and not r.prefilled
                        and not r.done and r.slot is None
                        and (self._eligible_rids is None
                             or r.rid in self._eligible_rids)),
                       key=lambda r: (r.arrival, r.rid))
        free = np.asarray([p.aqua.local_free
                           for p in self.kv.planes.values()], np.int64)
        picks: List = []
        for r in cands:
            if len(picks) >= max_rows or slack < self.kv.page_tokens:
                break
            n = min(slack, r.prompt_positions - 1 - r.prefill_pos)
            if n < self.kv.page_tokens:
                continue
            need = self.kv.pages_per_request(r.prefill_pos + n)
            if np.all(need <= free):
                picks.append((r, n))
                slack -= n
                free = free - need
        return picks

    def _fused_step(self, live: List[ReqState], chunk_plan: List,
                    specs: List) -> tuple:
        """Pack the step's work into one ``api.serve_step_paged`` call.

        Rows ``[0, max_running)`` are the decode lanes (present whenever
        any resident request decodes; idle lanes point at scratch), the
        following rows one prompt chunk each — the run set's fair-share
        chunks plus the speculative chunk-ahead grants — bucket-padded in
        both axes. Returns ``(compute_time, metered_transfer_time)`` on
        the analytic clock, including the O(1) per-step launch overhead
        (``ModelCost.launch_time``)."""
        m = self.metrics
        rows_chunk = list(chunk_plan) + list(specs)
        spec_rids = {r.rid for r, _ in specs}
        if not live and not rows_chunk:
            m.prefill_tokens_trace.append(0)
            m.launch_trace.append(0)
            m.baseline_launch_trace.append(0)
            return 0.0, 0.0
        t_before = self.pager.meter.sim_time
        n_dec = self.max_running if live else 0
        # packed shapes: with a step budget, the chunk region is FIXED at
        # (max_running + 1 rows) x (budget bucket) whenever any chunk runs,
        # so the jit cache is provably flat in the number of admitted
        # requests (chunk rows — run-set chunks plus speculative grants —
        # are capped at that fixed row bucket by _pick_speculative);
        # the all-decode steady state stays at Tc = 1 with no chunk
        # region. Unbudgeted (step_tokens=None) chunks are whole prompts,
        # so their shapes ride the prompt-length bucket ladder instead.
        if not rows_chunk:
            Tc, Rp = 1, 0
        elif self.step_tokens is not None:
            Tc = bucket_tokens(self.step_tokens)
            Rp = bucket_tokens(self.max_running + 1, lo=1)
        else:
            Tc = bucket_tokens(max(n for _, n in rows_chunk))
            Rp = bucket_tokens(len(rows_chunk), lo=1)
        R = n_dec + Rp
        tokens = np.zeros((R, Tc), np.int32)
        q_starts = np.zeros((R,), np.int32)
        n_reals = np.zeros((R,), np.int32)
        row_rids: List[Optional[int]] = [None] * R
        prefix_rows = None
        if self.cfg.n_prefix_embeds:
            prefix_rows = [None] * R
        if live:
            n_reals[:n_dec] = 1              # idle lanes: token 0 at pos 0
            ctx_mean = float(np.mean([r.ctx_len for r in live]))
            for r in live:
                # the new token's position may cross into a fresh page: grow
                # the block tables (allocation guarantees LOCAL; parked
                # requests were already restored in _place). A decode append
                # landing in a still-shared page copies it first (CoW).
                self.kv.ensure_capacity(r.rid, r.ctx_len)
                self.kv.make_writable(r.rid, r.ctx_len - 1, r.ctx_len)
                row_rids[r.slot] = r.rid
                tokens[r.slot, 0] = (r.generated[-1] if r.generated
                                     else r.prompt_tokens[-1])
                q_starts[r.slot] = r.ctx_len - 1
        for j, (r, n) in enumerate(rows_chunk):
            row = n_dec + j
            start = r.prefill_pos
            if r.rid in spec_rids:
                if r.parked:
                    m.spec_restores += 1    # its prior prefix pages page in
                try:
                    self.kv.ensure_capacity(r.rid, start + n)
                except MemoryError:
                    # the run set's own same-step growth (fresh decode
                    # pages, CoW clones) beat _pick_speculative's advisory
                    # headroom check — speculation is opportunistic: hand
                    # back whatever the attempt pulled LOCAL and drop this
                    # grant and every later one (specs are the trailing
                    # rows; the later grants haven't allocated yet)
                    self.kv.park(r.rid, r.prefill_pos,
                                 prefer=self.offload_tier)
                    r.parked = True
                    specs = specs[:j - len(chunk_plan)]
                    rows_chunk = rows_chunk[:j]
                    break
            else:
                self.kv.ensure_capacity(r.rid, start + n)
            # copy-on-write: a fully-matched prompt recomputes its final
            # position INTO the shared tail page — clone it first
            self.kv.make_writable(r.rid, start, start + n)
            row_rids[row] = r.rid
            # a VLM request's first chunks cover its prefix-embedding rows,
            # whose token ids are dummies and whose residual rows come from
            # prefix_embeds instead
            idx = np.arange(n) + start - r.n_prefix
            text = idx >= 0
            tokens[row, :n][text] = np.asarray(r.prompt_tokens,
                                               np.int32)[idx[text]]
            q_starts[row] = start
            n_reals[row] = n
            if prefix_rows is not None:
                prefix_rows[row] = r.prefix_embeds
        pre = None
        if prefix_rows is not None:
            P, d = self.cfg.n_prefix_embeds, self.cfg.d_model
            zero = jnp.zeros((1, P, d), self.cfg.dtype())
            pre = jnp.concatenate([p if p is not None else zero
                                   for p in prefix_rows], axis=0)
        bt = self.kv.block_tables(row_rids, pad_to=self._pps_pad)
        logits, self.kv.pools = api.serve_step_paged(
            self.params, self.cfg, jnp.asarray(tokens), self.kv.pools, bt,
            jnp.asarray(q_starts), jnp.asarray(n_reals), n_decode=n_dec,
            prefix_embeds=pre, read_pps=self.kv.pps, impl=self.paged_impl)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))

        compute = 0.0
        ptoks = 0
        for j, (r, n) in enumerate(rows_chunk):
            r.prefill_pos += n
            if not r.n_prefix:
                # publish completed full prompt pages into the prefix index
                # so later arrivals with the same prefix adopt them
                self.kv.register_prefix(r.rid, r.prefill_pos)
            if r.prefilled:
                r.generated.append(int(nxt[n_dec + j]))
            m.prefills += 1
            ptoks += n
        for r, n in specs:
            m.spec_chunks += 1
            m.spec_tokens += n
            # hand the pages straight back: a speculative request is not
            # in the planned run set, and LOCAL must only hold that set
            self.kv.park(r.rid, r.prefill_pos, prefer=self.offload_tier)
            r.parked = True
        if live:
            for r in live:
                r.generated.append(int(nxt[r.slot]))
            # mixed step: the chunk rows share the decode launch's weight
            # pass, so their FLOPs hide under the memory-bound decode
            # stream (ModelCost.fused_step_time) instead of paying a
            # separate per-request launch sequence
            compute += self.cost.fused_step_time(self.hw, len(live),
                                                 ctx_mean,
                                                 self.weight_bytes, ptoks)
        elif ptoks:
            compute += self.cost.prefill_time(self.hw, ptoks)
        # ONE jitted call per step: launches stay O(1) in admitted requests
        compute += self.cost.launch_time(self.hw, 1)
        m.prefill_tokens_trace.append(ptoks)
        m.launch_trace.append(self.cost.n_layers)
        m.baseline_launch_trace.append(
            (len(rows_chunk) + (1 if live else 0)) * self.cost.n_layers)
        return compute, self.pager.meter.sim_time - t_before

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 1000):
        """Step until every submitted request finished (or ``max_steps``);
        honors pending coordinator reclaims before returning. Returns the
        engine's :class:`EngineMetrics`."""
        for _ in range(max_steps):
            if not (self.waiting or self.running):
                break
            self.step()
        if self.coord is not None:
            self._respond()        # don't leave leases dangling after drain
        return self.metrics

    # ------------------------------------------------------------------
    # crash-consistent snapshot / restore
    # ------------------------------------------------------------------
    def snapshot(self) -> Dict:
        """Serialize the FULL serving state to a plain dict — the journal
        record a crash-consistent restart replays.

        Carries: the constructor knobs needed to rebuild an
        equivalently-sized engine, every request's :class:`ReqState`
        (waiting, running and finished — prompts, generated tokens,
        prefill positions, deadlines, terminal stamps), the runtime's
        whole page state through :meth:`PagedStateRuntime.snapshot_state`
        (block tables, page PAYLOADS from whatever tier they sit on, the
        radix prefix tree), the admission controller's admitted set, the
        CFS slice phase, the drain gate and the metrics. Greedy decode
        has no sampler RNG, so no RNG state exists to carry — restart
        determinism is argmax + the chunk-split invariance of prefill.

        Read-only and side-effect-free; call BETWEEN steps (no step
        program in flight). Remote leases are NOT serialized — restored
        pages land on the host tier and the restored engine re-leases
        donor memory through its own constructor/coordinator path.

        Raises:
            PageLossError: a block table still references a LOST page
                (recovery must re-queue its victim before snapshotting).
        """
        def req(r: ReqState) -> Dict:
            return {"rid": r.rid, "arrival": r.arrival,
                    "prompt_tokens": list(r.prompt_tokens),
                    "max_new_tokens": r.max_new_tokens,
                    "generated": list(r.generated),
                    "prefill_pos": r.prefill_pos,
                    "n_prefix": r.n_prefix,
                    "prefix_embeds": (None if r.prefix_embeds is None
                                      else np.asarray(r.prefix_embeds)),
                    "shared_tokens": r.shared_tokens,
                    "ttft_step": r.ttft_step,
                    "finish_step": r.finish_step,
                    "lora_id": r.lora_id,
                    "deadline_s": r.deadline_s,
                    "ttft_deadline_s": r.ttft_deadline_s,
                    "terminal": r.terminal,
                    "cancel_reason": r.cancel_reason}

        metrics: Dict[str, object] = {}
        for f in dataclass_fields(EngineMetrics):
            v = getattr(self.metrics, f.name)
            metrics[f.name] = (dict(v) if isinstance(v, dict)
                               else list(v) if isinstance(v, list) else v)
        return {"version": 1,
                "config": dict(self._snap_knobs),
                "next_rid": self._next_rid,
                "running": [req(r) for r in self.running],
                "waiting": [req(r) for r in self.waiting],
                "finished": [req(r) for r in self.finished],
                "kv": self.kv.snapshot_state(),
                "admitted": (sorted(self.admission._admitted)
                             if self.admission is not None else None),
                "since_switch": getattr(self.sched, "_since_switch", None),
                "draining": self._draining,
                "metrics": metrics}

    @classmethod
    def restore(cls, cfg: ModelConfig, params, snapshot: Dict, *,
                mesh=None, faults=None,
                coordinator: Optional[Coordinator] = None,
                audit: bool = False, hw: HardwareProfile = TPU_V5E,
                **overrides) -> "ServingEngine":
        """Rebuild a serving engine from a :meth:`snapshot` dict — the
        crash-consistent restart path.

        A FRESH engine is constructed from the snapshot's carried knobs
        (``overrides`` win — e.g. attach a new fault injector), the
        runtime's page state is rebuilt payload-for-payload
        (:meth:`PagedStateRuntime.restore_state`; everything lands parked
        on the host tier), and every surviving request re-queues: former
        RUNNERS first (the next plan re-admits them ahead of the
        backlog), each marked parked exactly when it still owns pages.
        The finished list, metric counters, admitted set, CFS slice phase
        and drain gate carry over, so post-restart TTFT/RCT stamps stay
        on the same simulated clock.

        Every restored request then completes BIT-IDENTICALLY to an
        uninterrupted run: park/restore round-trips are exact, greedy
        decode is argmax, and prefill logits are chunk-split-invariant —
        the restart may schedule different chunks, never different
        tokens. Mesh collective counters start fresh, so audit restored
        engines with a NEW :class:`InvariantAuditor`.
        """
        knobs = dict(snapshot["config"])
        knobs.update(overrides)
        eng = cls(cfg, params, mesh=mesh, faults=faults,
                  coordinator=coordinator, audit=audit, hw=hw, **knobs)
        eng.kv.restore_state(snapshot["kv"])

        def req(d: Dict) -> ReqState:
            r = ReqState(d["rid"], d["arrival"], list(d["prompt_tokens"]),
                         d["max_new_tokens"], lora_id=d["lora_id"],
                         deadline_s=d["deadline_s"],
                         ttft_deadline_s=d["ttft_deadline_s"])
            r.generated = list(d["generated"])
            r.prefill_pos = d["prefill_pos"]
            r.n_prefix = d["n_prefix"]
            if d["prefix_embeds"] is not None:
                r.prefix_embeds = jnp.asarray(d["prefix_embeds"])
            r.shared_tokens = d["shared_tokens"]
            r.ttft_step = d["ttft_step"]
            r.finish_step = d["finish_step"]
            r.terminal = d["terminal"]
            r.cancel_reason = d["cancel_reason"]
            if any(r.rid in p.pages for p in eng.kv.planes.values()):
                r.parked = True      # its pages sit on the host tier
            return r

        eng.waiting = ([req(d) for d in snapshot["running"]]
                       + [req(d) for d in snapshot["waiting"]])
        eng.finished = [req(d) for d in snapshot["finished"]]
        eng._next_rid = int(snapshot["next_rid"])
        eng._draining = bool(snapshot["draining"])
        if eng.admission is not None and snapshot["admitted"] is not None:
            eng.admission._admitted = set(snapshot["admitted"])
        if (snapshot["since_switch"] is not None
                and hasattr(eng.sched, "_since_switch")):
            eng.sched._since_switch = snapshot["since_switch"]
        for k, v in snapshot["metrics"].items():
            setattr(eng.metrics, k, dict(v) if isinstance(v, dict)
                    else list(v) if isinstance(v, list) else v)
        return eng
