"""LoRA adapters: per-request fine-tuning deltas + an AQUA-offloaded adapter
cache (paper §6.1, Figs. 8/12).

The paper's vLLM integration loads/stores whole adapters as ONE tensor (their
fix for the many-small-copies problem) — mirrored here: an adapter is packed
into a single contiguous blob in the AquaTensor, so fetching a cold adapter is
one large fabric message instead of per-layer fragments.

``apply_lora`` patches q/v projections (the classic LoRA placement):
    W' = W + (alpha/r) * A @ B
used by the single-adapter serving example; the cache layer below is what the
multi-tenant benchmarks exercise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aqua_tensor import REMOTE, AquaTensor, TransferMeter


def init_adapter(key, cfg: ModelConfig, rank: int = 16, alpha: float = 32.0):
    """One (A, B) pair per layer for wq and wv."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    L = cfg.n_layers
    dt = cfg.dtype()
    def tn(k, shape, std):
        return (std * jax.random.truncated_normal(k, -2, 2, shape)).astype(dt)
    return {
        "alpha": alpha, "rank": rank,
        "q_a": tn(ks[0], (L, d, rank), 1.0 / math.sqrt(d)),
        "q_b": jnp.zeros((L, rank, cfg.n_heads * hd), dt),
        "v_a": tn(ks[1], (L, d, rank), 1.0 / math.sqrt(d)),
        "v_b": jnp.zeros((L, rank, cfg.n_kv_heads * hd), dt),
    }


def adapter_bytes(adapter: dict) -> int:
    return sum(v.nbytes for k, v in adapter.items() if hasattr(v, "nbytes"))


def apply_lora(params: dict, cfg: ModelConfig, adapter: dict) -> dict:
    """Merge the adapter into stacked block params (single-adapter serving)."""
    from repro.models.lm import group_size
    gs = group_size(cfg)
    if gs != 1:
        raise ValueError(
            f"{cfg.name}: adapter merge supported for homogeneous stacks "
            f"only (group size {gs})")
    scale = adapter["alpha"] / adapter["rank"]

    def patch(blocks):
        mix = blocks["sub0"]["mix"]
        dq = jnp.einsum("ldr,lrh->ldh", adapter["q_a"], adapter["q_b"]) * scale
        dv = jnp.einsum("ldr,lrh->ldh", adapter["v_a"], adapter["v_b"]) * scale
        mix = dict(mix, wq=dict(mix["wq"], w=mix["wq"]["w"] + dq.astype(mix["wq"]["w"].dtype)),
                   wv=dict(mix["wv"], w=mix["wv"]["w"] + dv.astype(mix["wv"]["w"].dtype)))
        return dict(blocks, sub0=dict(blocks["sub0"], mix=mix))

    return dict(params, blocks=patch(params["blocks"]))


class AdapterCache:
    """LRU adapter cache over an AquaTensor: hot adapters LOCAL, cold ones on
    the donor GPU (fabric) or host. Fetch = one coalesced blob transfer.

    Adapters page in their NATIVE dtype: every array leaf is raveled into one
    contiguous vector of ``dtype`` (pass the model's param dtype) — the
    paper's "load the adapter as one tensor" fix with no f32 blowup, on the
    same page machinery every other state tier now uses.
    """

    def __init__(self, *, capacity_local: int, page_elems: int = 65536,
                 dtype=jnp.float32, meter: Optional[TransferMeter] = None):
        self.capacity = capacity_local
        self.page_elems = page_elems
        self.aqua = AquaTensor(
            n_logical=4096, page_shape=(page_elems,),
            local_slots=max(capacity_local * 2, 4), host_slots=4096,
            dtype=dtype, meter=meter, name="lora")
        self._parked: Dict[int, tuple] = {}
        self._lru: list = []

    def put(self, aid: int, adapter: dict):
        leaves = jax.tree.leaves(adapter_arrays(adapter))
        flat = jnp.concatenate(
            [l.reshape(-1).astype(self.aqua.dtype) for l in leaves])
        n_pages = -(-flat.size // self.page_elems)
        flat = jnp.pad(flat, (0, n_pages * self.page_elems - flat.size))
        lps = self.aqua.allocate(n_pages, prefer=REMOTE)
        self.aqua.write(lps, flat.reshape(n_pages, self.page_elems))
        # the python dict is retained alongside the paged blob: fetch()
        # meters the coalesced page-in and returns the retained object
        self._parked[aid] = (lps, adapter)

    def fetch(self, aid: int) -> dict:
        """Bring an adapter into the local tier (metered if cold)."""
        lps, adapter = self._parked[aid]
        hit = aid in self._lru
        if not hit:
            self.aqua.read(lps, meter=True)   # the coalesced fabric fetch
            self._lru.append(aid)
            if len(self._lru) > self.capacity:
                self._lru.pop(0)              # evictions are free (read-only copy)
        else:
            self._lru.remove(aid)
            self._lru.append(aid)
        return adapter

    @property
    def hits_resident(self):
        return list(self._lru)


def adapter_arrays(adapter: dict) -> dict:
    return {k: v for k, v in adapter.items() if hasattr(v, "nbytes")}
