"""Prompt schedulers: FCFS continuous batching (vLLM-style) and the
completely fair scheduler (paper §5) — shared by the real engine and the
discrete-event simulator.

Capacity planning is in PAGES, not slots: when constructed with a
``page_cost`` callback (pages a request needs LOCAL if scheduled) and a
``page_budget`` (the LOCAL pool sizes), the run set is chosen so its pages
fit the local tier — the block-table analogue of vLLM's KV-memory admission
gate. Cost and budget are PER-PLANE vectors (np arrays, one entry per page
plane of the unified state runtime: kv / mla token pages, ssm / conv / wkv /
shift state pages); a request fits only when EVERY plane fits. Scalars keep
working for single-plane callers. Without cost/budget the plan degrades to
slot counting.

Budgets are PHYSICAL pages: a ``page_cost`` callback may accept a second
argument — the run set chosen so far — and return the request's MARGINAL
cost given it (the engine discounts pages shared copy-on-write with an
already-chosen request), so two requests aliasing a prompt prefix cost the
prefix once and shared prefixes directly raise admission capacity.

Step execution is budgeted in TOKENS (``split_step_budget``): every step
spends at most ``step_tokens`` tokens, split between the decode lanes (one
each) and prompt-prefill CHUNKS of the run set's not-yet-prefilled requests.
A long prompt therefore never monopolizes a step — its prefill is spread
over several bounded steps while short prompts' chunks and everyone's decode
tokens ride along (chunked continuous batching, Kossmann et al. 2024).
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np


@dataclass
class ReqState:
    rid: int
    arrival: float
    prompt_tokens: List[int]
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None            # batch slot when running
    parked: object = None                 # truthy while paged out
    prefill_pos: int = 0                  # prompt POSITIONS whose state is written
    n_prefix: int = 0                     # VLM prefix-embedding positions
    prefix_embeds: object = None          # (1, n_prefix, d) array when VLM
    shared_tokens: int = 0                # prompt prefix adopted from the
    #                                       prefix index (CoW page sharing)
    ttft_step: Optional[int] = None
    finish_step: Optional[int] = None
    lora_id: Optional[int] = None
    deadline_s: Optional[float] = None    # e2e deadline, seconds after arrival
    ttft_deadline_s: Optional[float] = None  # first-token deadline, same base
    terminal: Optional[str] = None        # set ONLY by the engine's _retire:
    #                                       "finished" | "cancelled" | "expired"
    cancel_reason: Optional[str] = None   # "client" | "deadline" | "fault" | ...

    @property
    def lifecycle(self) -> str:
        """Derived lifecycle state — never stored, so it cannot drift from
        the fields that define it: ``waiting`` → ``prefilling`` → ``running``
        → one of the terminal states stamped by the engine's ``_retire``
        (``finished`` / ``cancelled`` / ``expired``)."""
        if self.terminal is not None:
            return self.terminal
        if self.done:
            return "finished"
        if self.prefilled:
            return "running"
        if self.prefill_pos > 0 or self.slot is not None:
            return "prefilling"
        return "waiting"

    @property
    def prompt_positions(self) -> int:
        """Positions the prompt occupies: VLM prefix embeds + text tokens."""
        return self.n_prefix + len(self.prompt_tokens)

    @property
    def prefilled(self) -> bool:
        return self.prefill_pos >= self.prompt_positions

    @property
    def vruntime(self) -> int:            # CFS: service received = tokens out
        return len(self.generated)

    @property
    def ctx_len(self) -> int:
        return self.prompt_positions + len(self.generated)

    @property
    def resident_tokens(self) -> int:
        """Tokens whose K/V is materialized in the cache right now: prefilled
        prompt tokens plus every generated token but the newest (its K/V is
        appended at the next decode step)."""
        return self.prefill_pos + max(len(self.generated) - 1, 0)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclass
class Decision:
    """One step's plan: ``run`` is the set that should be resident, ``admit``
    the subset of it still needing prefill, ``preempt`` the currently-
    resident requests to page out (always empty for FCFS)."""
    run: List[ReqState]                   # the set that should be resident
    admit: List[ReqState]                 # subset of run needing prefill
    preempt: List[ReqState]               # currently-resident to page out


def split_step_budget(step_tokens: Optional[int], decode_lanes: int,
                      prefill_remaining: Sequence[int], *,
                      flops_slack: Optional[int] = None) -> List[int]:
    """Split one step's token budget into prefill chunk sizes.

    ``decode_lanes`` tokens are reserved for the resident decoding requests
    (one each); the remainder is FAIR-SHARED among the pending prefills so a
    short prompt's chunk rides the same step as a long prompt's — the long
    prefill can no longer monopolize a step (that is the TTFT-under-burst
    fix). Shares that a short prompt cannot use spill over to the others.
    ``step_tokens=None`` disables budgeting: every pending prefill gets its
    full remaining prompt in one chunk (the unchunked baseline).
    Returns one chunk size (possibly 0) per entry of ``prefill_remaining``.

    ``flops_slack`` (``ModelCost.piggyback_tokens``) additionally caps the
    chunk budget at the decode launch's memory-bound FLOPs slack: a mixed
    step is priced at ``max(t_flops, t_mem)``, so chunk tokens inside the
    window ride the decode launch's weight/KV stream FOR FREE while every
    token beyond it extends the step linearly — the roofline-aware sizing
    keeps mixed steps exactly AT the crossover instead of past it.

    When the decode lanes alone consume the whole budget (or the FLOPs
    window is empty), one token is still granted (progress floor): an
    admitted prefill holding a batch slot must never starve behind a
    saturated decode batch, so a step may exceed the budget by at most one
    token.
    """
    rem = [max(r, 0) for r in prefill_remaining]
    if step_tokens is None:
        return rem
    left = max(step_tokens - decode_lanes, 1 if any(rem) else 0)
    if flops_slack is not None:
        left = max(min(left, int(flops_slack)), 1 if any(rem) else 0)
    chunks = [0] * len(rem)
    while left > 0:
        active = [i for i in range(len(rem)) if chunks[i] < rem[i]]
        if not active:
            break
        share = max(left // len(active), 1)
        for i in active:
            take = min(share, rem[i] - chunks[i], left)
            chunks[i] += take
            left -= take
            if left == 0:
                break
    return chunks


def bucket_tokens(n: int, *, lo: int = 8) -> int:
    """Pad a chunk length up to its shape bucket (powers of two from ``lo``),
    so the jit cache holds one trace per bucket instead of one per distinct
    prompt/chunk length."""
    b = lo
    while b < n:
        b *= 2
    return b


def _cost_takes_chosen(page_cost) -> bool:
    """True when a ``page_cost`` callback accepts ``(request, chosen)`` —
    the marginal-cost form that lets the caller discount pages shared with
    the run set picked so far. Single-argument callbacks keep working."""
    if page_cost is None:
        return False
    try:
        params = [p for p in inspect.signature(page_cost).parameters.values()
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD,
                                p.VAR_POSITIONAL)]
    except (TypeError, ValueError):      # builtins / odd callables
        return False
    return (any(p.kind == p.VAR_POSITIONAL for p in params)
            or len(params) >= 2)


class FCFSScheduler:
    """vLLM-like: admit in arrival order while slots (and, when page-aware,
    the LOCAL page budget) allow; never preempt. Under memory pressure,
    later arrivals starve (paper Fig. 1a)."""

    def __init__(self, max_running: int, *,
                 page_cost: Optional[Callable[[ReqState], int]] = None,
                 page_budget: Optional[int] = None):
        """Args:
            max_running: batch-slot cap on the run set.
            page_cost: pages a request needs LOCAL if scheduled — scalar or
                per-plane vector; may take ``(request, chosen)`` to return
                the marginal cost given the partially-built run set.
            page_budget: LOCAL pool size(s) the run set must fit.
        """
        self.max_running = max_running
        self.page_cost = page_cost
        self.page_budget = page_budget
        self._marginal = _cost_takes_chosen(page_cost)

    def _cost(self, r: ReqState, chosen: Sequence[ReqState]):
        return (self.page_cost(r, chosen) if self._marginal
                else self.page_cost(r))

    def update_budget(self, page_budget) -> None:
        """Re-plan admission against a new LOCAL/physical budget — the
        engine calls this after a lease shrink or donor loss contracts the
        tiers the run set's pages can live in."""
        self.page_budget = page_budget

    def plan(self, step: int, waiting: Sequence[ReqState],
             running: Sequence[ReqState]) -> Decision:
        """Plan one step: keep everything running, admit waiters in arrival
        order while the slot cap and the PHYSICAL page budget hold (shared
        prefix pages are counted once across the run set via the marginal
        ``page_cost``). Never preempts. Returns a :class:`Decision`."""
        run = list(running)
        pages = 0
        if self.page_cost is not None:
            chosen: List[ReqState] = []
            for r in run:
                pages = pages + self._cost(r, chosen)
                chosen.append(r)
        admit = []
        for r in sorted(waiting, key=lambda r: (r.arrival, r.rid)):
            if len(run) >= self.max_running:
                break
            if self.page_cost is not None and self.page_budget is not None:
                c = self._cost(r, run)
                if run and np.any(pages + c > self.page_budget):
                    break                     # strict FCFS: no skip-ahead
                pages = pages + c
            run.append(r)
            admit.append(r)
        return Decision(run, admit, [])

    def peek(self, step: int, waiting: Sequence[ReqState],
             running: Sequence[ReqState]) -> Decision:
        """Non-binding preview of the next plan (FCFS planning is stateless),
        used by the engine to prefetch page restores during the current step."""
        return self.plan(step, waiting, running)


class CFSScheduler:
    """Completely fair scheduler: every `slice_tokens` generated tokens, the
    requests with the LEAST service run next (paper §5) — as many as fit the
    slot cap and, when page-aware, the LOCAL page budget."""

    def __init__(self, max_running: int, slice_tokens: int = 5, *,
                 page_cost: Optional[Callable[[ReqState], int]] = None,
                 page_budget: Optional[int] = None,
                 prefix_group: Optional[Callable[[ReqState], object]] = None):
        """Args:
            max_running: batch-slot cap on the run set.
            slice_tokens: tokens each resident request decodes between
                fair-pick boundaries.
            page_cost / page_budget: as in :class:`FCFSScheduler` —
                ``page_cost`` may take ``(request, chosen)`` for marginal
                (shared-prefix-discounted) physical-page costing.
            prefix_group: co-scheduling key — requests sharing a radix
                prefix return the same (hashable) group. At a fair-pick
                boundary, same-group requests WITHIN a vruntime class are
                clustered behind the group's earliest member, so sharers
                are admitted by the same plan and their shared prefix
                parks/restores once per plan instead of thrashing between
                interleaved singletons. Clustering never crosses vruntime
                classes — fairness order is untouched.
        """
        self.max_running = max_running
        self.slice_tokens = slice_tokens
        self.page_cost = page_cost
        self.page_budget = page_budget
        self.prefix_group = prefix_group
        self._marginal = _cost_takes_chosen(page_cost)
        self._since_switch = 0

    def _cost(self, r: ReqState, chosen: Sequence[ReqState]):
        return (self.page_cost(r, chosen) if self._marginal
                else self.page_cost(r))

    def _pick_key(self, everyone: Sequence[ReqState]):
        """Fair-pick sort key. Without a ``prefix_group`` callback this is
        (vruntime, arrival, rid). With one, requests sharing a group sort
        behind the group's earliest (arrival, rid) member WITHIN their
        vruntime class — the greedy budget walk then meets sharers
        adjacently and admits them in one plan, so their common prefix
        flips tiers once per plan."""
        if self.prefix_group is None:
            return lambda r: (r.vruntime, r.arrival, r.rid)
        anchor: dict = {}
        for r in everyone:
            g = self.prefix_group(r)
            if g is None:
                continue
            k, me = (r.vruntime, g), (r.arrival, r.rid)
            if k not in anchor or me < anchor[k]:
                anchor[k] = me

        def key(r: ReqState):
            g = self.prefix_group(r)
            a = (anchor[(r.vruntime, g)] if g is not None
                 else (r.arrival, r.rid))
            return (r.vruntime, a, r.arrival, r.rid)
        return key

    def update_budget(self, page_budget) -> None:
        """Re-plan fair picks against a new LOCAL/physical budget (see
        :meth:`FCFSScheduler.update_budget`)."""
        self.page_budget = page_budget

    def plan(self, step: int, waiting: Sequence[ReqState],
             running: Sequence[ReqState]) -> Decision:
        """Plan one step. Off a slice boundary the current run set stands;
        on one, the least-served requests that fit the slot cap and the
        PHYSICAL page budget run next (a request whose pages alias an
        already-picked sharer's prefix pays only its exclusive pages, so
        shared prefixes admit strictly larger fair sets; with a
        ``prefix_group`` key, equal-vruntime sharers are clustered so one
        plan admits them together). Requests falling out of the set are
        returned in ``Decision.preempt``."""
        self._since_switch += 1
        boundary = (self._since_switch >= self.slice_tokens) or not running
        if not boundary:
            return Decision(list(running), [], [])
        self._since_switch = 0
        everyone = list(waiting) + list(running)
        everyone.sort(key=self._pick_key(everyone))
        if self.page_cost is None or self.page_budget is None:
            run = everyone[: self.max_running]
        else:
            run, pages = [], 0
            for r in everyone:
                if len(run) >= self.max_running:
                    break
                c = self._cost(r, run)
                if run and np.any(pages + c > self.page_budget):
                    continue                  # fair-pick the next that fits
                run.append(r)
                pages = pages + c
        run_ids = {r.rid for r in run}
        preempt = [r for r in running if r.rid not in run_ids]
        admit = [r for r in run if r.slot is None and not r.prefilled]
        return Decision(run, admit, preempt)

    def peek(self, step: int, waiting: Sequence[ReqState],
             running: Sequence[ReqState]) -> Decision:
        """Non-binding preview of the next plan: same decision the next
        ``plan`` call will make, with the slice counter restored — the engine
        uses it to issue restore prefetches that overlap this step's compute."""
        saved = self._since_switch
        try:
            return self.plan(step, waiting, running)
        finally:
            self._since_switch = saved


def fairness_spread(requests: Sequence[ReqState]) -> int:
    """Max-min service spread across unfinished requests — including the
    never-admitted (a starved request sits at vruntime 0, which is the
    unfairness FCFS exhibits). CFS bounds this by ~slice_tokens x rotation;
    FCFS lets it grow to the full generation length (paper Fig. 1a)."""
    live = [r for r in requests if not r.done]
    if len(live) < 2:
        return 0
    v = [r.vruntime for r in live]
    return max(v) - min(v)
