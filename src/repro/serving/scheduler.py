"""Prompt schedulers: FCFS continuous batching (vLLM-style) and the
completely fair scheduler (paper §5) — shared by the real engine and the
discrete-event simulator.

Capacity planning is in PAGES, not slots: when constructed with a
``page_cost`` callback (pages a request needs LOCAL if scheduled) and a
``page_budget`` (the LOCAL pool size), the run set is chosen so its pages
fit the local tier — the block-table analogue of vLLM's KV-memory admission
gate. Without them (the dense shim) the plan degrades to slot counting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence


@dataclass
class ReqState:
    rid: int
    arrival: float
    prompt_tokens: List[int]
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None            # batch slot when running
    parked: object = None                 # ParkedContext when preempted
    prefilled: bool = False
    ttft_step: Optional[int] = None
    finish_step: Optional[int] = None
    lora_id: Optional[int] = None

    @property
    def vruntime(self) -> int:            # CFS: service received = tokens out
        return len(self.generated)

    @property
    def ctx_len(self) -> int:
        return len(self.prompt_tokens) + len(self.generated)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclass
class Decision:
    run: List[ReqState]                   # the set that should be resident
    admit: List[ReqState]                 # subset of run needing prefill
    preempt: List[ReqState]               # currently-resident to page out


class FCFSScheduler:
    """vLLM-like: admit in arrival order while slots (and, when page-aware,
    the LOCAL page budget) allow; never preempt. Under memory pressure,
    later arrivals starve (paper Fig. 1a)."""

    def __init__(self, max_running: int, *,
                 page_cost: Optional[Callable[[ReqState], int]] = None,
                 page_budget: Optional[int] = None):
        self.max_running = max_running
        self.page_cost = page_cost
        self.page_budget = page_budget

    def plan(self, step: int, waiting: Sequence[ReqState],
             running: Sequence[ReqState]) -> Decision:
        run = list(running)
        pages = sum(self.page_cost(r) for r in run) if self.page_cost else 0
        admit = []
        for r in sorted(waiting, key=lambda r: (r.arrival, r.rid)):
            if len(run) >= self.max_running:
                break
            if self.page_cost is not None and self.page_budget is not None:
                c = self.page_cost(r)
                if run and pages + c > self.page_budget:
                    break                     # strict FCFS: no skip-ahead
                pages += c
            run.append(r)
            admit.append(r)
        return Decision(run, admit, [])


class CFSScheduler:
    """Completely fair scheduler: every `slice_tokens` generated tokens, the
    requests with the LEAST service run next (paper §5) — as many as fit the
    slot cap and, when page-aware, the LOCAL page budget."""

    def __init__(self, max_running: int, slice_tokens: int = 5, *,
                 page_cost: Optional[Callable[[ReqState], int]] = None,
                 page_budget: Optional[int] = None):
        self.max_running = max_running
        self.slice_tokens = slice_tokens
        self.page_cost = page_cost
        self.page_budget = page_budget
        self._since_switch = 0

    def plan(self, step: int, waiting: Sequence[ReqState],
             running: Sequence[ReqState]) -> Decision:
        self._since_switch += 1
        boundary = (self._since_switch >= self.slice_tokens) or not running
        if not boundary:
            return Decision(list(running), [], [])
        self._since_switch = 0
        everyone = list(waiting) + list(running)
        everyone.sort(key=lambda r: (r.vruntime, r.arrival, r.rid))
        if self.page_cost is None or self.page_budget is None:
            run = everyone[: self.max_running]
        else:
            run, pages = [], 0
            for r in everyone:
                if len(run) >= self.max_running:
                    break
                c = self.page_cost(r)
                if run and pages + c > self.page_budget:
                    continue                  # fair-pick the next that fits
                run.append(r)
                pages += c
        run_ids = {r.rid for r in run}
        preempt = [r for r in running if r.rid not in run_ids]
        admit = [r for r in run if r.slot is None and not r.prefilled]
        return Decision(run, admit, preempt)


def fairness_spread(requests: Sequence[ReqState]) -> int:
    """Max-min service spread across unfinished requests — including the
    never-admitted (a starved request sits at vruntime 0, which is the
    unfairness FCFS exhibits). CFS bounds this by ~slice_tokens x rotation;
    FCFS lets it grow to the full generation length (paper Fig. 1a)."""
    live = [r for r in requests if not r.done]
    if len(live) < 2:
        return 0
    v = [r.vruntime for r in live]
    return max(v) - min(v)
