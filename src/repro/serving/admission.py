"""SLO-aware admission control: keep the serving system inside its
KV-occupancy stability region under bursty arrivals.

Why admission at all: the schedulers (``serving/scheduler.py``) budget the
RUN SET — the requests resident this step — against the LOCAL page pools.
That bounds *instantaneous* occupancy but not its *trajectory*: a naive
admission gate prices a request at its CURRENT context (prompt only, at
arrival) while its KV grows by one token per decode step until completion.
Under a burst, the in-flight set's committed future occupancy silently
overshoots capacity; every subsequent step then pays page churn (swap out
a grown victim, page the queue head in, repeat) and the token-generation
rate collapses exactly when the arrival rate spikes — service-induced
congestion (Ao et al.), the unresponsiveness the paper measures against.

The stability region (Nie et al.'s KV-constrained framework, discrete
form): the system is stable only while the token-GENERATION rate at the
current budget covers the token-ACCUMULATION rate of the in-flight set —
equivalently, while the in-flight set's projected KV-occupancy trajectory
(each request growing to its terminal context, freeing at completion)
stays inside the page budget. :class:`AdmissionController` enforces
exactly that: each candidate is priced via the same marginal per-plane
page-cost vectors the schedulers use (shared prefixes discounted, PR 4/8)
plus its TERMINAL cost at completion, a piecewise-linear occupancy
trajectory is projected for the committed set, and the candidate is
admitted only while the combined trajectory's peak stays below
``headroom`` x budget. Everything else is DEFERRED — degrade-to-queue,
never reject-with-error: a deferred request simply waits for completions
to reopen the region (so this module never raises on the admit path; a CI
grep-guard pins it to typed ``AquaError`` subclasses).

Prefill/decode mixing (Kossmann et al.'s half-empty techniques): while
live decode lanes exist, at most ``prefill_admit_limit`` requests may be
in their prefill phase at once — a burst of new prompts must not turn
every step into prefill work and starve the decode lanes' SLO.

The controller is clock-agnostic: the engine instantiates it over
per-plane PAGE vectors (``PagedStateRuntime`` costs), the discrete-event
simulator over BYTES (``ModelCost`` context bytes) — one stability
criterion, two clocks, mirroring the scheduler-sharing idiom of the repo.
Budgets are read through a callable each step, so the engine's
``_replan_capacity`` (lease shrink / donor loss contracting the tiers)
shrinks the stability region with no extra wiring.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.errors import AdmissionError


class AdmissionController:
    """Stability-region admission over caller-supplied cost callables.

    Args:
        budget: zero-arg callable returning the per-plane page budget (or a
            1-vector of bytes on the analytic clock). Re-read every
            ``filter`` call so lease shrinks / donor losses contract the
            stability region automatically.
        current_cost: ``(request, chosen) -> vector`` — the request's
            occupancy RIGHT NOW, marginal against the committed set chosen
            so far (shared prefix pages/bytes counted once).
        terminal_cost: ``(request, chosen) -> vector`` — occupancy at
            COMPLETION (context grown to prompt + max_new tokens), same
            marginal convention. This is what naive current-cost admission
            ignores and what the trajectory grows toward.
        remaining_tokens: ``request -> (prefill_remaining, decode_remaining)``
            in tokens — sets the projection's time base.
        headroom: fraction of the budget the projected trajectory may fill
            (the remainder absorbs projection error: CoW clones, page
            rounding, chunk-rate variance). Must be in (0, 1].
        step_tokens: the engine/simulator step token budget — prefill
            advances at roughly this rate fair-shared across live prefills;
            ``None`` means whole-prompt prefill (one step).
        prefill_admit_limit: max requests simultaneously in their prefill
            phase while any committed request is decoding (``None`` = no
            mixing cap).
        slo_ttft_s / step_time: optional SLO observability — with both
            given, each admission's projected prefill-completion time is
            checked against the TTFT SLO and ``slo_at_risk`` counts the
            admissions projected to miss it (observational only: the
            response to overload is deferral, which the stability check
            already does).
        horizon: projection length cap in steps. Internally the trajectory
            is discretized into a fixed number of bins spanning the
            horizon (peaks are checked per bin, ramps rounded UP a bin —
            conservative), so ``filter``'s cost is independent of horizon.
        order_key: candidate-ordering key. Default is arrival order
            ``(arrival, rid)``; the engine passes an earliest-deadline-first
            key so deadline-carrying requests are priced (and admitted)
            before slack ones — urgency, not just age, decides who enters
            the region first.
        deadline_of: ``request -> Optional[seconds]`` — the request's
            REMAINING end-to-end deadline slack (``None`` = no deadline).
            With ``step_time`` also given, a candidate whose projected
            finish lies past its remaining slack is DOOMED — it would hold
            pages only to be shed at expiry — so it is deferred and, more
            importantly, EXCLUDED from the projected-occupancy trajectory:
            work that will miss anyway must not shrink the region for work
            that can still make it. The engine's deadline sweep reclaims
            the doomed request once its clock actually runs out.

    Raises:
        AdmissionError: invalid configuration (bad headroom/horizon). The
            admit/defer path itself never raises.
    """

    def __init__(self, *, budget: Callable[[], np.ndarray],
                 current_cost: Callable, terminal_cost: Callable,
                 remaining_tokens: Callable,
                 headroom: float = 0.9,
                 step_tokens: Optional[int] = None,
                 prefill_admit_limit: Optional[int] = 4,
                 slo_ttft_s: Optional[float] = None,
                 step_time: Optional[Callable[[], float]] = None,
                 horizon: int = 4096,
                 order_key: Optional[Callable] = None,
                 deadline_of: Optional[Callable] = None):
        if not 0.0 < headroom <= 1.0:
            raise AdmissionError(f"headroom={headroom} not in (0, 1]")
        if horizon < 1:
            raise AdmissionError(f"horizon={horizon} must be >= 1")
        if prefill_admit_limit is not None and prefill_admit_limit < 1:
            raise AdmissionError("prefill_admit_limit must be >= 1 (zero "
                                 "would deadlock a cold system)")
        self._budget = budget
        self._current = current_cost
        self._terminal = terminal_cost
        self._remaining = remaining_tokens
        self.headroom = float(headroom)
        self.step_tokens = step_tokens
        self.prefill_admit_limit = prefill_admit_limit
        self.slo_ttft_s = slo_ttft_s
        self._step_time = step_time
        self._order_key = order_key or (lambda r: (r.arrival, r.rid))
        self._deadline_of = deadline_of
        self.horizon = int(horizon)
        # fixed-resolution projection: `_bins` samples across the horizon
        # keep the per-candidate cost O(bins) no matter how long requests
        # live; each bin covers `_bin_steps` engine steps
        self._bins = min(self.horizon, 192)
        self._bin_steps = max(1, -(-self.horizon // self._bins))
        self._admitted: set = set()
        # observability
        self.admitted_total = 0
        self.deferred_total = 0          # defer decisions (per filter call)
        self.slo_at_risk = 0
        self.deadline_doomed = 0         # deferrals because finish > slack
        self.occupancy_frac = 0.0        # committed t=0 occupancy / budget
        self.projected_peak_frac = 0.0   # committed trajectory peak / budget
        self.decisions: Deque[Dict] = deque(maxlen=4096)

    # ------------------------------------------------------------------
    def _curve(self, r, chosen: Sequence, n_prefill_live: int
               ) -> Tuple[np.ndarray, np.ndarray, int]:
        """(cost_now, cost_terminal, finish_step) for one request.

        The projected occupancy is linear from ``cost_now`` to
        ``cost_terminal`` over its remaining steps (prefill at the
        fair-shared chunk rate, then one decode token per step), dropping
        to zero at ``finish_step`` when completion frees the pages."""
        c_now = np.asarray(self._current(r, chosen), np.float64)
        c_term = np.asarray(self._terminal(r, chosen), np.float64)
        p_rem, d_rem = self._remaining(r)
        p_rem, d_rem = max(int(p_rem), 0), max(int(d_rem), 0)
        if p_rem == 0:
            steps_p = 0
        elif self.step_tokens is None:
            steps_p = 1
        else:
            rate = max(self.step_tokens // max(n_prefill_live, 1), 1)
            steps_p = -(-p_rem // rate)
        return c_now, np.maximum(c_term, c_now), steps_p + d_rem

    def _add_curve(self, traj: np.ndarray, c_now: np.ndarray,
                   c_term: np.ndarray, finish: int) -> np.ndarray:
        """Add one request's piecewise-linear occupancy to the committed
        trajectory ``traj`` (shape ``(_bins, n_planes)``; each bin spans
        ``_bin_steps`` engine steps and holds the request's occupancy at
        the bin's END — the ramp's maximum over the bin, conservative)."""
        B = traj.shape[0]
        k = min(max(-(-finish // self._bin_steps), 1), B)
        ramp = np.linspace(1.0 / k, 1.0, k, endpoint=True)[:, None]
        traj[:k] += c_now[None, :] * (1.0 - ramp) + c_term[None, :] * ramp
        # pages free at completion: nothing added past `finish`. A request
        # whose completion lies past the horizon holds its terminal cost at
        # the horizon's edge (conservative).
        if finish > B * self._bin_steps:
            traj[k:] += c_term[None, :]
        return traj

    # ------------------------------------------------------------------
    def filter(self, waiting: Sequence, running: Sequence
               ) -> Tuple[List, List]:
        """Partition ``waiting`` into (eligible, deferred) for this step.

        Previously admitted requests (including CFS-preempted ones cycling
        through the waiting list) stay eligible unconditionally — admission
        is a one-way gate ahead of the scheduler, it never fights the fair
        pick. New candidates are walked in arrival order and admitted while
        the committed occupancy trajectory (running + already admitted +
        candidate) peaks below ``headroom`` x budget and the prefill-mixing
        cap holds. Later small candidates may be admitted past an earlier
        deferred large one (admission is not FCFS-strict — bounding
        occupancy is the point); the deferred one retries every step and
        admits as completions reopen the region.

        Progress floor: with nothing running and nothing eligible, the
        head-of-line candidate passes through regardless — the scheduler's
        own budget walk decides, so one over-region request on an idle
        system degrades to the scheduler's behavior instead of deadlocking.
        """
        budget = np.asarray(self._budget(), np.float64)
        region = self.headroom * budget
        committed: List = list(running)
        eligible: List = []
        deferred: List = []
        candidates: List = []
        for r in waiting:
            if r.rid in self._admitted:
                committed.append(r)
                eligible.append(r)
            else:
                candidates.append(r)
        n_prefill_live = sum(1 for r in committed
                             if self._remaining(r)[0] > 0)
        any_decode = any(self._remaining(r)[0] == 0 for r in committed)
        traj = np.zeros((self._bins, len(budget)), np.float64)
        chosen: List = []
        for r in committed:
            c_now, c_term, fin = self._curve(r, chosen, n_prefill_live)
            traj = self._add_curve(traj, c_now, c_term, fin)
            chosen.append(r)
        self.occupancy_frac = float(np.max(traj[0] / np.maximum(budget, 1.0)))

        n_prefilling = n_prefill_live
        for r in sorted(candidates, key=self._order_key):
            mix_ok = (self.prefill_admit_limit is None or not any_decode
                      or n_prefilling < self.prefill_admit_limit)
            c_now, c_term, fin = self._curve(r, chosen,
                                             max(n_prefilling, 1))
            # a candidate that cannot finish inside its deadline slack is
            # excluded from the trajectory: admitting it would spend region
            # on work the deadline sweep will shed anyway
            doomed = False
            if self._deadline_of is not None and self._step_time is not None:
                slack = self._deadline_of(r)
                if slack is not None and fin * self._step_time() > slack:
                    doomed = True
                    self.deadline_doomed += 1
            cand = self._add_curve(traj.copy(), c_now, c_term, fin)
            fits = bool(np.all(cand.max(axis=0) <= region))
            admit = fits and mix_ok and not doomed
            self.decisions.append({
                "rid": r.rid, "admitted": admit, "fits": fits,
                "mix_ok": mix_ok, "doomed": doomed, "cost_now": c_now.copy(),
                "occupancy_before": traj[0].copy(), "budget": budget.copy(),
                "projected_peak": cand.max(axis=0).copy()})
            if admit:
                traj = cand
                chosen.append(r)
                eligible.append(r)
                self._admitted.add(r.rid)
                self.admitted_total += 1
                if self._remaining(r)[0] > 0:
                    n_prefilling += 1
                if (self.slo_ttft_s is not None
                        and self._step_time is not None):
                    steps_p = fin - self._remaining(r)[1]
                    if steps_p * self._step_time() > self.slo_ttft_s:
                        self.slo_at_risk += 1
            else:
                deferred.append(r)
                self.deferred_total += 1
        self.projected_peak_frac = float(
            np.max(traj.max(axis=0) / np.maximum(budget, 1.0)))

        if not running and not eligible and deferred:
            # progress floor: an idle system must not deadlock behind a
            # request whose terminal footprint alone exceeds the region
            head = min(deferred, key=self._order_key)
            deferred.remove(head)
            eligible.append(head)
            self._admitted.add(head.rid)
            self.admitted_total += 1
        return eligible, deferred

    # ------------------------------------------------------------------
    def forget(self, rid: int) -> None:
        """Drop a request from the admitted set — called at retirement
        (its pages are free) and on lost-page recovery (the request resets
        to prefill position 0 and must re-price against the contracted
        region before re-entering)."""
        self._admitted.discard(rid)

    @property
    def admitted_rids(self) -> set:
        return set(self._admitted)
