"""Typed error hierarchy for the serving runtime.

Every deliberate failure the paged serving stack can raise derives from
:class:`AquaError`, so callers distinguish the three classes of trouble by
TYPE instead of parsing message strings:

  * recoverable data-plane faults (``PageLossError``, ``TransferFaultError``)
    — the engine owns a recovery policy for each (recompute from the prompt,
    bounded retry-with-backoff);
  * control-plane lease faults (``LeaseRevokedError``) — a donor that shrank
    or revoked its lease must never be addressed again;
  * invariant violations (``SchedulingInvariantError``,
    ``InvariantViolation``) — bugs, never recovered from, always loud.

Genuine capacity exhaustion stays ``MemoryError`` (``AquaTensor`` raising
"all tiers full"): it is the contract the page-budget-aware schedulers are
designed around and the signal opportunistic allocations (speculative
chunks, CoW clones) already handle. Bare asserts and untyped raises in
serving hot paths are banned by a CI grep-guard; everything intentional
raises one of these (or a stdlib ``ValueError`` for caller-input mistakes).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple


class AquaError(RuntimeError):
    """Base class of every intentional serving-runtime failure."""


class PageLossError(AquaError):
    """Pages became irrecoverable (their donor died holding the only copy).

    Raised when a lost-tier page is read, migrated, or ensured LOCAL. The
    engine's recovery policy: release the victim request's surviving pages,
    re-queue it, and RECOMPUTE its context from the prompt (prefill restarts
    past any still-resident shared prefix) instead of crashing the step.
    """

    def __init__(self, message: str, *, plane: Optional[str] = None,
                 pages: Sequence[int] = ()):
        super().__init__(message)
        self.plane = plane
        self.pages: Tuple[int, ...] = tuple(int(p) for p in pages)


class LeaseRevokedError(AquaError):
    """A transfer leg or lease operation addressed a donor whose lease is
    gone (permanent loss, or revoked by the donor). Unlike a transient leg
    fault this is never retried — the slab no longer exists."""

    def __init__(self, message: str, *, donor: Optional[str] = None):
        super().__init__(message)
        self.donor = donor


class TransferFaultError(AquaError):
    """A transfer leg kept failing past the bounded retry budget. With a
    :class:`~repro.core.faults.FaultInjector` whose transient faults respect
    ``max_consecutive`` this is unreachable; it fires only when a leg is
    configured to fail persistently (``leg_fault_rate=1``) — an operator
    signal, not a recovery path."""

    def __init__(self, message: str, *, tier: Optional[int] = None,
                 donor: Optional[str] = None, attempts: int = 0):
        super().__init__(message)
        self.tier = tier
        self.donor = donor
        self.attempts = attempts


class SchedulingInvariantError(AquaError):
    """The planned run set violated an engine invariant (e.g. more requests
    than free batch slots) — a scheduler bug that must fail loudly instead of
    silently skipping placement and serving the request never."""


class InvariantViolation(AquaError):
    """The :class:`~repro.core.faults.InvariantAuditor` found the runtime
    inconsistent (refcounts vs block tables vs physical occupancy vs
    meter/collective counts). Carries every violation found in one pass."""

    def __init__(self, violations: Sequence[str]):
        self.violations: Tuple[str, ...] = tuple(violations)
        lines = "\n  - ".join(self.violations)
        super().__init__(f"{len(self.violations)} invariant violation(s):"
                         f"\n  - {lines}")


class CancelledError(AquaError):
    """The request was torn down before completion — by a client cancel
    (``ServingEngine.cancel``), a missed deadline (the engine's per-step
    deadline sweep), or a seeded ``"cancel"`` fault event.

    Cancellation is a NORMAL lifecycle outcome, not a fault: the engine's
    recovery policy is the same teardown the finish ladder performs (free
    the batch slot, release every plane page through refcounts, un-pin
    prefetched restores, ``admission.forget``) plus publication of the
    completed prefix pages into the radix cache so the work is not wasted.
    Raised only on the RESULT path (``ServingEngine.output``) when a caller
    asks for the tokens of a cancelled request — never from ``cancel``
    itself, which is idempotent and returns a bool."""

    def __init__(self, message: str, *, rid: Optional[int] = None,
                 reason: Optional[str] = None):
        super().__init__(message)
        self.rid = rid
        self.reason = reason


class EngineCrashError(AquaError):
    """A seeded ``"engine_crash"`` fault event fired: the serving process
    dies mid-stream, losing every page table, the radix cache and all
    in-flight state. The recovery policy is crash-consistent restart —
    discard the crashed engine and rebuild from the latest
    ``ServingEngine.snapshot()`` journal via ``ServingEngine.restore``;
    greedy decode makes the resumed streams bit-identical, and the recovery
    time is the trajectory ``BENCH_lifecycle.json`` tracks."""


class CapacityError(AquaError):
    """A serving unit cannot physically hold the configured workload (e.g.
    the model weights alone exceed device memory) — a sizing mistake caught
    at construction, not a runtime fault."""


class AdmissionError(AquaError):
    """The SLO-aware admission controller was misconfigured (bad headroom,
    budget, or callback wiring) — caught at construction or the first
    ``filter`` call. NEVER raised on the admit/defer path itself: admission
    degrades overload to queueing, it does not reject requests with errors
    (a deferred request simply waits for the stability region to reopen).
    A CI grep-guard pins ``serving/admission.py`` to raising only typed
    :class:`AquaError` subclasses."""
