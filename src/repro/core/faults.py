"""Fault injection + invariant auditing for the tiered serving runtime.

The mesh-real tier domain (distributed/mesh_tiers.py) made donors physical;
this module makes them MORTAL. Production scale-up domains lose transfer
legs transiently (a congested fabric hop), lose donors permanently (the
peer's process dies), and — the ROADMAP's named gap — have donors shrink
their leases dynamically when their OWN serving load needs the HBM back.
Every one of those must be a priced, recoverable event rather than an
undefined state.

Two pieces:

``FaultInjector``
    A deterministic, seedable oracle the data plane consults at every
    transfer leg and lease boundary. Three fault classes:

      * transient leg failures — Bernoulli per (tier, donor) leg at
        ``leg_fault_rate``, with a per-leg consecutive-failure streak capped
        at ``max_consecutive`` (the cap forces the next attempt to succeed),
        so bounded retry-with-backoff provably converges below
        ``max_leg_retries`` and the recovery path stays deterministic for a
        given seed;
      * permanent donor loss — scheduled ``donor_loss`` events; once a donor
        is marked lost every leg addressing it raises
        :class:`~repro.core.errors.LeaseRevokedError` and its resident pages
        become the LOST tier (:class:`~repro.core.errors.PageLossError` on
        touch);
      * dynamic lease shrinkage — scheduled ``lease_shrink`` events: the
        donor reclaims a fraction of its slots and the runtime live-migrates
        the occupants to other donors or the HOST tier.

    Scheduled events carry EITHER an engine-step trigger (``at_step``) or an
    analytic-clock trigger (``at_time``) so the same schedule drives the
    real engine and the discrete-event simulator.

    Failed attempts are decided BEFORE a collective is issued, so the mesh
    domain's physical ``collectives`` counter only ever counts successful
    legs; retries are priced (full message time + exponential backoff,
    ``TransferMeter.record_retry``) and counted in the meter's
    ``retries_fabric`` / ``retries_host`` — never in ``messages_*``.

``InvariantAuditor``
    One consistency oracle for every recovery path: refcounts vs block
    tables, free lists vs physical tier occupancy, LOCAL pins vs active
    referencers, the prefix index vs live pages, meter vs mesh collective
    counts, and (given the engine) batch-slot bookkeeping. Runs after every
    engine step under ``ServingEngine(audit=True)`` and inside the chaos
    tests; any inconsistency raises
    :class:`~repro.core.errors.InvariantViolation` listing every failed
    check at once.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.errors import InvariantViolation


@dataclass
class FaultEvent:
    """One scheduled control-plane fault.

    kind: ``"donor_loss"`` (the peer dies holding its slab),
    ``"lease_shrink"`` (the donor reclaims ``frac`` of its slots),
    ``"cancel"`` (the client abandons request ``rid`` — engine/simulator
    tear it out of whatever lifecycle state it is in and reclaim its
    pages), or ``"engine_crash"`` (the serving process dies: the engine
    raises :class:`~repro.core.errors.EngineCrashError` and the harness
    recovers via ``ServingEngine.restore`` from the latest snapshot).
    Exactly one of ``at_step`` (engine-step clock) / ``at_time`` (analytic
    seconds) should be set; the matching clock's poll fires it once.
    """
    kind: str
    donor: str = ""
    frac: float = 1.0
    rid: Optional[int] = None
    at_step: Optional[int] = None
    at_time: Optional[float] = None
    fired: bool = field(default=False, compare=False)


class FaultInjector:
    """Deterministic, seedable fault oracle for transfer legs and leases.

    Args:
        seed: RNG seed — the whole fault trace is a pure function of it.
        leg_fault_rate: Bernoulli probability a transfer-leg attempt fails.
        max_consecutive: cap on consecutive failures of one (tier, donor)
            leg; once reached the next attempt is FORCED to succeed. Keep it
            below ``max_leg_retries`` and bounded retry always converges.
        max_leg_retries: retry budget per leg before the runtime gives up
            with ``TransferFaultError`` (only reachable when transient
            faults are configured unbounded, e.g. ``max_consecutive=0``
            semantics are not supported — the floor is 1).
        events: scheduled :class:`FaultEvent` list (donor loss / shrink).
    """

    def __init__(self, *, seed: int = 0, leg_fault_rate: float = 0.0,
                 max_consecutive: int = 2, max_leg_retries: int = 6,
                 events: Sequence[FaultEvent] = ()):
        if not 0.0 <= leg_fault_rate <= 1.0:
            raise ValueError(f"leg_fault_rate={leg_fault_rate} not in [0, 1]")
        if max_consecutive < 1:
            raise ValueError("max_consecutive must be >= 1 (a leg that can "
                             "never succeed is donor loss, not a transient)")
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.leg_fault_rate = float(leg_fault_rate)
        self.max_consecutive = int(max_consecutive)
        self.max_leg_retries = int(max_leg_retries)
        self.events: List[FaultEvent] = list(events)
        self._streak: Dict[Tuple[int, Optional[str]], int] = {}
        self._lost: Set[str] = set()
        # observability: everything injected, for tests and benchmarks
        self.leg_faults_injected = 0
        self.events_fired: List[FaultEvent] = []

    # -- transient leg faults ---------------------------------------------
    def leg_fails(self, tier, donor: Optional[str] = None) -> bool:
        """One Bernoulli draw for a transfer-leg attempt on (tier, donor).

        ``tier`` is any hashable leg key — the runtime passes its int tier
        constants, the analytic simulator its tier name strings.

        A leg whose consecutive-failure streak reached ``max_consecutive``
        is forced to succeed (streak resets) — the determinism contract that
        keeps bounded retry convergent for any seed."""
        if self.leg_fault_rate <= 0.0:
            return False
        key = (tier, donor)
        if self._streak.get(key, 0) >= self.max_consecutive:
            self._streak[key] = 0
            return False
        if self.rng.random() < self.leg_fault_rate:
            self._streak[key] = self._streak.get(key, 0) + 1
            self.leg_faults_injected += 1
            return True
        self._streak[key] = 0
        return False

    # -- permanent donor loss ---------------------------------------------
    def mark_donor_lost(self, donor: str):
        """Record a donor as permanently gone: every later leg or lease
        operation addressing it must raise ``LeaseRevokedError``."""
        self._lost.add(donor)

    def donor_lost(self, donor: Optional[str]) -> bool:
        return donor is not None and donor in self._lost

    @property
    def lost_donors(self) -> Set[str]:
        return set(self._lost)

    # -- scheduled events ---------------------------------------------------
    def due_events(self, *, step: Optional[int] = None,
                   now: Optional[float] = None) -> List[FaultEvent]:
        """Pop every not-yet-fired event due on the calling clock.

        Engine callers pass ``step`` (fires ``at_step`` events); simulator
        callers pass ``now`` in analytic seconds (fires ``at_time`` events).
        Each event fires exactly once, in schedule order."""
        due = []
        for ev in self.events:
            if ev.fired:
                continue
            if ev.at_step is not None and step is not None \
                    and step >= ev.at_step:
                due.append(ev)
            elif ev.at_time is not None and now is not None \
                    and now >= ev.at_time:
                due.append(ev)
        for ev in due:
            ev.fired = True
            self.events_fired.append(ev)
        return due


class InvariantAuditor:
    """Consistency oracle over the paged runtime (+ optionally the engine).

    ``check`` returns a list of human-readable violations (empty = clean);
    ``audit`` raises :class:`InvariantViolation` carrying all of them. The
    mesh message/collective check is STATEFUL (deltas since the previous
    audit of the same runtime), so construct one auditor per engine/test.
    """

    def __init__(self):
        self._last_collectives: Optional[int] = None
        self._last_messages: Optional[float] = None
        self.audits = 0

    # ------------------------------------------------------------------
    def audit(self, runtime, *, engine=None) -> None:
        bad = self.check(runtime, engine=engine)
        if bad:
            raise InvariantViolation(bad)

    def check(self, runtime, *, engine=None) -> List[str]:
        """Audit a :class:`~repro.serving.kv_cache.PagedStateRuntime`.

        Checks, per plane:
          1. free lists and page-table occupancy PARTITION every tier's
             physical slots (no slot leaked, none double-booked);
          2. every page's refcount equals the number of block tables
             referencing it (+1 for the plane's scratch page), and every
             refcount-0-but-resident page is a legal CACHED page: caching
             enabled, indexed in exactly one radix block, never pinned,
             never LOST;
          3. LOCAL pin counts equal the number of ACTIVE referencers, and
             every pinned page is LOCAL;
          4. no block table references a LOST-tier page (recovery must
             re-queue every victim before the audit);
          5. the radix tree is well-formed (children keyed by their first
             block, page-aligned edges, parent links intact), every node
             page is allocated, each page appears in exactly one block,
             and the reverse map agrees in both directions.
        Runtime-wide: mesh collectives vs priced fabric messages move in
        lockstep (every priced message is backed by >= 1 physical
        collective; retries are priced but never issue one). With
        ``engine``: batch slots partition and the scheduler budget does not
        exceed what the tiers can physically hold.
        """
        from repro.core.aqua_tensor import (HOST, LOCAL, LOST, REMOTE,
                                            TIER_NAMES)
        self.audits += 1
        bad: List[str] = []
        for name, plane in runtime.planes.items():
            aq = plane.aqua
            pt = aq.page_table
            # -- 1. free-list / occupancy partition per tier --------------
            def _partition(tier, used_slots, free_list, capacity, label):
                used = [int(s) for s in used_slots]
                if len(set(free_list)) != len(free_list):
                    bad.append(f"{name}/{label}: duplicate free slots")
                overlap = set(free_list) & set(used)
                if overlap:
                    bad.append(f"{name}/{label}: slots {sorted(overlap)} "
                               "both free and occupied")
                if len(used) != len(set(used)):
                    bad.append(f"{name}/{label}: double-booked slots")
                covered = set(free_list) | set(used)
                expect = set(range(capacity))
                if covered != expect:
                    missing = sorted(expect - covered)[:8]
                    extra = sorted(covered - expect)[:8]
                    bad.append(f"{name}/{label}: slot partition broken "
                               f"(missing {missing}, out-of-range {extra})")

            _partition(LOCAL, pt[pt[:, 0] == LOCAL, 1], aq._free_local,
                       aq.local_pool.shape[0], "local")
            _partition(HOST, pt[pt[:, 0] == HOST, 1], aq._free_host,
                       aq.host_pool.shape[0], "host")
            for donor, free in aq._remote_free.items():
                di = aq._donors.index(donor)
                used = pt[(pt[:, 0] == REMOTE) & (pt[:, 2] == di), 1]
                _partition(REMOTE, used, free,
                           aq.remote_capacity.get(donor, 0), f"remote:{donor}")
            # a donor with pages but no pool (and not marked LOST) leaked
            for di_val in np.unique(pt[pt[:, 0] == REMOTE, 2]):
                donor = aq._donors[int(di_val)]
                if donor not in aq.remote_pools:
                    bad.append(f"{name}: pages on donor {donor} but its "
                               "lease is gone")

            # -- 2 + 3. refcounts and pins vs block tables ----------------
            refs: Dict[int, int] = {}
            active_refs: Dict[int, int] = {}
            for rid, rows in plane.pages.items():
                seen = set()
                for row in rows:
                    for lp in row:
                        lp = int(lp)
                        if lp in seen:
                            continue      # one ref per (request, page)
                        seen.add(lp)
                        refs[lp] = refs.get(lp, 0) + 1
                        if rid in runtime._active:
                            active_refs[lp] = active_refs.get(lp, 0) + 1
            refs[plane.scratch_lp] = refs.get(plane.scratch_lp, 0) + 1
            allocated = set(np.nonzero(pt[:, 0] != -1)[0].tolist())
            for lp in sorted(set(refs) | allocated):
                want = refs.get(lp, 0)
                have = int(aq.page_refs[lp])
                if pt[lp, 0] == -1:
                    bad.append(f"{name}: page {lp} referenced but "
                               "unallocated")
                elif want != have:
                    bad.append(f"{name}: page {lp} refcount {have} != "
                               f"{want} block-table referencer(s)")
                elif want == 0:
                    # resident with no referencer: legal only as a CACHED
                    # page owned by the radix index
                    if not getattr(runtime, "caching", False):
                        bad.append(f"{name}: page {lp} resident at "
                                   "refcount 0 but caching is off (leak)")
                    elif (name, lp) not in runtime._lp_node:
                        bad.append(f"{name}: cached page {lp} not in the "
                                   "radix index (leak)")
                    if plane.pin.get(lp, 0):
                        bad.append(f"{name}: cached page {lp} is pinned")
                    if pt[lp, 0] == LOST:
                        bad.append(f"{name}: cached page {lp} sits in the "
                                   "LOST tier (donor death must drop it)")
            for lp, c in plane.pin.items():
                want = active_refs.get(int(lp), 0)
                if c != want:
                    bad.append(f"{name}: page {lp} pin {c} != {want} "
                               "active referencer(s)")
                if pt[lp, 0] != LOCAL:
                    bad.append(f"{name}: pinned page {lp} is "
                               f"{TIER_NAMES.get(int(pt[lp, 0]), '?')}, "
                               "not local")
            for lp, c in active_refs.items():
                if c > 0 and plane.pin.get(lp, 0) != c:
                    bad.append(f"{name}: page {lp} active refs {c} but pin "
                               f"{plane.pin.get(lp, 0)}")

            # -- 4. lost pages must have been recovered away --------------
            lost_ref = [lp for lp in refs
                        if lp != plane.scratch_lp and pt[lp, 0] == LOST]
            if lost_ref:
                bad.append(f"{name}: block tables still reference LOST "
                           f"pages {sorted(lost_ref)[:8]}")

        # -- 5. radix tree <-> reverse map <-> live pages ------------------
        seen_pages: Dict = {}
        for seed, root in runtime._roots.items():
            stack = list(root.children.items())
            while stack:
                key, node = stack.pop()
                if not node.blocks or node.blocks[0] != key:
                    bad.append(f"radix child of seed {seed!r} keyed by a "
                               "block that is not its first block")
                if len(node.blocks) != len(node.pages):
                    bad.append(f"radix node has {len(node.blocks)} blocks "
                               f"but {len(node.pages)} page sets")
                for bt in node.blocks:
                    if len(bt) != runtime.page_tokens:
                        bad.append("radix edge block is not page-aligned "
                                   f"({len(bt)} tokens)")
                for bi, pagedict in enumerate(node.pages):
                    for name, lps in pagedict.items():
                        aq = runtime.planes[name].aqua
                        for lp in lps:
                            lp = int(lp)
                            if aq.page_table[lp, 0] == -1:
                                bad.append(f"radix node points at freed "
                                           f"{name} page {lp}")
                            k = (name, lp)
                            if k in seen_pages:
                                bad.append(f"{name} page {lp} appears in "
                                           "two radix blocks")
                            seen_pages[k] = (node, bi)
                            if runtime._lp_node.get(k) != (node, bi):
                                bad.append("radix reverse map disagrees "
                                           f"for {name} page {lp}")
                for ckey, child in node.children.items():
                    if child.parent is not node:
                        bad.append("radix child parent link broken")
                    stack.append((ckey, child))
        for k in runtime._lp_node:
            if k not in seen_pages:
                bad.append(f"reverse map entry {k} -> unreachable radix "
                           "node")

        # -- mesh collectives vs priced fabric messages --------------------
        mesh = getattr(runtime, "mesh", None)
        if mesh is not None:
            c, m = mesh.collectives, runtime.meter.messages_fabric
            if self._last_collectives is not None:
                dc = c - self._last_collectives
                dm = m - self._last_messages
                if dm > dc:
                    bad.append(f"meter priced {dm} fabric messages but only "
                               f"{dc} collectives were issued (retries must "
                               "never count as messages)")
            self._last_collectives, self._last_messages = c, m

        # -- engine bookkeeping -------------------------------------------
        if engine is not None:
            slots = [r.slot for r in engine.running if r.slot is not None]
            if len(slots) != len(set(slots)):
                bad.append(f"duplicate batch slots {sorted(slots)}")
            if len(slots) != len(engine.running):
                bad.append("running request without a batch slot")
            covered = set(slots) | set(engine._free_slots)
            if covered != set(range(engine.max_running)) \
                    or len(engine._free_slots) != len(set(engine._free_slots)):
                bad.append("batch slots do not partition "
                           f"(used={sorted(slots)}, "
                           f"free={sorted(engine._free_slots)})")
            cap = engine.kv.total_capacity()
            if np.any(np.asarray(engine.sched.page_budget) > cap):
                bad.append(f"scheduler budget {engine.sched.page_budget} "
                           f"exceeds physical tier capacity {cap}")
            # no pin survives its referencer: every ACTIVE (pin-holding)
            # rid must still be a live engine request — a retired/cancelled
            # rid left in _active would hold its pages pinned LOCAL forever
            live = ({r.rid for r in engine.running}
                    | {r.rid for r in engine.waiting})
            orphans = sorted(set(runtime._active) - live)
            if orphans:
                bad.append(f"active (pinned) rids {orphans[:8]} have no "
                           "live request — a pin survived its referencer")
            # prefetched restores must reference live waiting requests only
            stale = sorted(r.rid for r in getattr(engine, "_prefetched", [])
                           if r.rid not in live)
            if stale:
                bad.append(f"prefetched restore(s) for retired rid(s) "
                           f"{stale[:8]} — release must clear prefetch pins")
        return bad
