"""Bursty serving workloads: the request streams the paper measures under.

The paper's headline claim is *responsiveness under bursty request
patterns* — its 20x responsiveness win is measured against baselines that
go unresponsive during arrival spikes. This module is the workload side of
that claim: seedable generators producing :class:`~repro.core.simulator.
Request` streams with the three properties production LLM traffic actually
has, so the admission controller (``serving/admission.py``) and the burst
benchmark (``benchmarks/burst_stability.py``) are exercised against the
load that breaks naive admission:

  * **heavy-tailed lengths** — prompt and output lengths are lognormal
    (a few very long prompts/generations dominate the byte budget, the
    regime where current-occupancy admission over-commits future KV);
  * **Poisson-modulated arrival spikes** — arrivals follow a two-state
    modulated Poisson process: a baseline rate, with configurable windows
    during which the rate multiplies by ``burst_factor`` (the "10x spike"
    of the stability benchmark);
  * **multi-tenant prefix mixes** — tenants own system prompts shared by
    their requests (``prefix_group`` / ``shared_prefix_len``), with
    Zipf-like traffic shares, generalizing the prefix-cache benchmark's
    generator (which moved here; ``benchmarks.common`` re-exports it).

Every generator is a pure function of its seed: the same arguments produce
a bit-identical trace (pinned by ``tests/test_burst_stability.py``), so a
divergence between two runs is a scheduler/controller change, never the
workload.

``prompt_tokens_for`` maps a generated stream onto concrete token ids for
the REAL engine (same shared prefix tokens for same ``prefix_group``), so
one trace drives both clocks — the discrete-event simulator and
``ServingEngine.submit``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import Request


@dataclass(frozen=True)
class BurstSpec:
    """One arrival-rate spike window on top of the baseline Poisson rate.

    Between windows arrivals are Poisson at ``base_rate``; inside
    ``[start, start + duration)`` the rate is ``base_rate * factor``
    (``factor=10`` is the benchmark's headline spike). Windows may overlap;
    the rate at time t is ``base_rate * max(1, factors of windows covering
    t)`` — spikes modulate, they do not stack multiplicatively.
    """
    start: float
    duration: float
    factor: float = 10.0


def rate_at(t: float, base_rate: float, bursts: Sequence[BurstSpec]) -> float:
    """Instantaneous arrival rate of the modulated Poisson process at t."""
    f = 1.0
    for b in bursts:
        if b.start <= t < b.start + b.duration:
            f = max(f, b.factor)
    return base_rate * f


def _thinned_arrivals(rng: np.random.Generator, n: int, base_rate: float,
                      bursts: Sequence[BurstSpec]) -> List[float]:
    """First ``n`` arrival times of the modulated Poisson process, by
    thinning: draw candidate arrivals at the envelope (max) rate and keep
    each with probability rate(t)/envelope — exact for piecewise-constant
    rates, and deterministic for a given rng state."""
    env = base_rate * max([b.factor for b in bursts], default=1.0)
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        t += float(rng.exponential(1.0 / env))
        if rng.random() < rate_at(t, base_rate, bursts) / env:
            out.append(t)
    return out


def make_bursty_requests(n: int, *, seed: int = 0, base_rate: float = 2.0,
                         bursts: Sequence[BurstSpec] = (),
                         prompt_median: float = 384.0,
                         prompt_sigma: float = 0.7,
                         gen_median: float = 256.0,
                         gen_sigma: float = 0.9,
                         max_prompt: int = 8192, max_gen: int = 4096,
                         n_tenants: int = 0,
                         system_prompt: Tuple[int, int] = (256, 1024),
                         lora_bytes: float = 0.0) -> List[Request]:
    """A bursty, heavy-tailed, optionally multi-tenant request stream.

    Args:
        n: number of requests.
        seed: RNG seed — the trace is a pure function of the arguments.
        base_rate: baseline Poisson arrival rate (requests/s).
        bursts: :class:`BurstSpec` spike windows modulating the rate.
        prompt_median/prompt_sigma: lognormal prompt-length parameters
            (median tokens, log-space sigma — sigma ~0.7 gives a p99/median
            ratio of ~5, the heavy tail).
        gen_median/gen_sigma: same for the output length. Output sigma
            defaults HEAVIER than the prompt's: generation lengths are the
            unobservable-at-admission quantity whose tail drives KV
            occupancy overshoot.
        max_prompt/max_gen: hard clamps (the engine's ``max_seq`` analogue).
        n_tenants: 0 for single-tenant traffic; otherwise each request
            belongs to a tenant drawn from a Zipf-like 1/rank share, and
            carries the tenant's system prompt as its shared prefix
            (``prefix_group`` = tenant id, ``shared_prefix_len`` = the
            tenant's system-prompt length, log-uniform in
            ``system_prompt``). The per-request tail rides ON TOP of the
            system prompt.
        lora_bytes: per-request adapter bytes (simulator LoRA pricing).

    Returns:
        ``Request`` list sorted by arrival, ``rid`` = arrival order.
    """
    rng = np.random.default_rng(seed)
    arrivals = _thinned_arrivals(rng, n, base_rate, bursts)
    sys_len: List[int] = []
    share = None
    if n_tenants > 0:
        lo, hi = system_prompt
        sys_len = [int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
                   for _ in range(n_tenants)]
        share = np.array([1.0 / (1 + t) for t in range(n_tenants)])
        share /= share.sum()
    reqs: List[Request] = []
    for i, at in enumerate(arrivals):
        p = int(rng.lognormal(np.log(prompt_median), prompt_sigma)) + 1
        g = int(rng.lognormal(np.log(gen_median), gen_sigma)) + 1
        group: Optional[int] = None
        shared = 0
        if n_tenants > 0:
            tenant = int(rng.choice(n_tenants, p=share))
            group, shared = tenant, sys_len[tenant]
            p = shared + min(p, max(max_prompt - shared, 1))
        reqs.append(Request(i, float(at), min(p, max_prompt),
                            min(g, max_gen), lora_bytes=lora_bytes,
                            prefix_group=group, shared_prefix_len=shared))
    return reqs


def make_multi_tenant_requests(n: int, n_tenants: int = 6, seed: int = 0,
                               system_prompt=(1024, 3072),
                               tail_mean: float = 96.0,
                               gen=(40, 120), burst: float = 1.0,
                               think_time: float = 30.0) -> List[Request]:
    """Heavy-tailed multi-tenant stream for the prefix-cache benchmarks.

    Each tenant owns a system prompt (its ``prefix_group``) whose length is
    log-uniform in ``system_prompt``; per-request tails are lognormal
    (median ``tail_mean``, heavy right tail) and arrivals come in tenant
    bursts separated by exponential think time, so later members of a
    burst typically land AFTER the leader finished — the load where a
    refcount-0 cache wins and pure live sharing does not. Tenant traffic
    shares follow a Zipf-like 1/rank law (a few hot tenants, a long cold
    tail).

    The trace is a pure function of the arguments (seed-determinism pinned
    by ``tests/test_burst_stability.py``). Historically lived in
    ``benchmarks.common``, which still re-exports it.
    """
    rng = np.random.default_rng(seed)
    lo, hi = system_prompt
    sys_len = [int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
               for _ in range(n_tenants)]
    share = np.array([1.0 / (1 + t) for t in range(n_tenants)])
    share /= share.sum()
    reqs, t, i = [], 0.0, 0
    while i < n:
        tenant = int(rng.choice(n_tenants, p=share))
        t += rng.exponential(think_time)
        k = min(1 + rng.poisson(burst), n - i)
        at = t
        for _ in range(k):
            tail = int(rng.lognormal(np.log(tail_mean), 0.8)) + 1
            reqs.append(Request(
                i, float(at), sys_len[tenant] + tail,
                int(rng.integers(*gen)), prefix_group=tenant,
                shared_prefix_len=sys_len[tenant]))
            at += rng.exponential(1.0)
            i += 1
    reqs.sort(key=lambda r: r.arrival)
    for j, r in enumerate(reqs):     # rid order == arrival order
        r.rid = j
    return reqs


def make_cancel_events(requests: Sequence[Request], *, frac: float = 0.2,
                       seed: int = 0, mean_wait_s: float = 5.0) -> List:
    """Seedable client-abandonment schedule for a request stream.

    A ``frac`` subset of ``requests`` is cancelled ``Exp(mean_wait_s)``
    seconds after its arrival — the impatient-client model of Ao et al.,
    where abandoned work that is NOT reclaimed is what drives congestion
    collapse. Returns ``FaultEvent(kind="cancel", rid=..., at_time=...)``
    sorted by fire time; both clocks consume it (the simulator via
    ``due_events(now=t)``, the engine via its dual-clock fault poll). The
    schedule is a pure function of the arguments, like every generator in
    this module.
    """
    from repro.core.faults import FaultEvent
    if not 0.0 <= frac <= 1.0:
        raise ValueError(f"frac={frac} not in [0, 1]")
    rng = np.random.default_rng((seed, 3))
    events = []
    for r in requests:
        if rng.random() < frac:
            wait = float(rng.exponential(mean_wait_s))
            events.append(FaultEvent(kind="cancel", rid=r.rid,
                                     at_time=r.arrival + wait))
    events.sort(key=lambda ev: ev.at_time)
    return events


def prompt_tokens_for(requests: Sequence[Request], *, vocab: int = 251,
                      seed: int = 0) -> Dict[int, List[int]]:
    """Concrete token ids for a generated stream, for the REAL engine.

    Requests with the same ``prefix_group`` share the SAME first
    ``shared_prefix_len`` token ids (so the engine's radix prefix index
    actually aliases their pages), with per-request tails drawn from a
    deterministic per-rid stream — the same trace therefore drives both
    clocks: the simulator prices it analytically, the engine runs it
    through ``submit(prompt_tokens, max_new_tokens, arrival=...)``.

    Token id 0 is avoided (many smoke configs reserve it for padding).
    Returns ``{rid: [token ids]}``.
    """
    prefixes: Dict[object, List[int]] = {}
    out: Dict[int, List[int]] = {}
    for r in requests:
        toks: List[int] = []
        if r.prefix_group is not None and r.shared_prefix_len > 0:
            if r.prefix_group not in prefixes:
                g = np.random.default_rng((seed, 1, int(r.prefix_group)))
                prefixes[r.prefix_group] = (
                    1 + g.integers(0, vocab - 1,
                                   size=r.shared_prefix_len)).tolist()
            toks.extend(prefixes[r.prefix_group][:r.shared_prefix_len])
        tail = r.prompt_len - len(toks)
        if tail > 0:
            g = np.random.default_rng((seed, 2, int(r.rid)))
            toks.extend((1 + g.integers(0, vocab - 1, size=tail)).tolist())
        out[r.rid] = toks[:r.prompt_len]
    return out
