"""Calibrated analytical performance model.

Two hardware profiles:
  * ``A100_NVLINK``  — the paper's testbed (8x A100-80G, NVLink/NVSwitch,
    PCIe-attached host DRAM). Used to reproduce the paper's figures
    quantitatively (Fig. 1/3a/7/9/10/12/13).
  * ``TPU_V5E``      — the port target (per-chip constants from the brief:
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI). Used for the
    roofline analysis and the TPU-constant variants of each benchmark.

The interconnect model is latency + bandwidth: t(s) = alpha + s / B_peak, so
effective bandwidth  s / t(s)  reproduces the paper's Fig. 3a shape — tiny
messages see almost no benefit over PCIe, and the NVLink curve crosses
100 GB/s around 2 MB, reaching ~250 GB/s for large buffers. This is the
quantitative basis of the AQUA TENSORS coalescing requirement.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class LinkModel:
    name: str
    peak_bw: float          # bytes/s
    latency: float          # s per message

    def time(self, nbytes: float, n_messages: int = 1) -> float:
        return n_messages * self.latency + nbytes / self.peak_bw

    def effective_bw(self, message_bytes: float) -> float:
        return message_bytes / self.time(message_bytes)


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    flops_peak: float       # FLOP/s (bf16)
    hbm_bw: float           # bytes/s
    hbm_bytes: float        # device memory capacity
    fabric: LinkModel       # scale-up interconnect (NVLink / ICI)
    host_link: LinkModel    # PCIe path to host DRAM
    mfu: float = 0.45       # achievable fraction of peak in serving kernels
    membw_util: float = 0.75
    # per-kernel-launch dispatch overhead (CUDA launch + driver ~3-10 us;
    # XLA dispatch on TPU is the same order). The serving loop's hidden tax:
    # a step that issues one call PER ADMITTED REQUEST pays this once per
    # request per layer, which is the between-launch idle regime of
    # "Is the GPU Half-Empty or Half-Full?" (Kossmann et al. 2024).
    launch_overhead: float = 4e-6
    # base delay before re-issuing a FAILED transfer leg (fault injection):
    # detecting the failure (timeout / NACK) plus requeueing the collective.
    # Doubles per consecutive retry (retry_backoff_time).
    retry_backoff: float = 25e-6

    def pod_slice(self, n: int) -> "HardwareProfile":
        """Aggregate n TP-sharded chips into one logical serving unit (a 34B
        model does not fit one 16 GB v5e chip; it is served by a TP group).
        Compute/HBM scale with n; each chip pages its own shard concurrently,
        so aggregate fabric/host bandwidth scales too (latency does not).
        Launch overhead does NOT shrink: every chip dispatches the same
        kernel sequence in lockstep."""
        if n == 1:
            return self
        return HardwareProfile(
            f"{self.name}x{n}", self.flops_peak * n, self.hbm_bw * n,
            self.hbm_bytes * n,
            LinkModel(self.fabric.name, self.fabric.peak_bw * n,
                      self.fabric.latency),
            LinkModel(self.host_link.name, self.host_link.peak_bw * n,
                      self.host_link.latency),
            self.mfu, self.membw_util, self.launch_overhead,
            self.retry_backoff)


# Paper testbed: A100-80G SXM. Fig. 3a calibration: 100 GB/s @ 2 MB, ~250 GB/s peak
#  => alpha = 2e6/100e9 - 2e6/250e9 = 12 us.
A100_NVLINK = HardwareProfile(
    name="a100-nvlink",
    flops_peak=312e12,
    hbm_bw=2.0e12,
    hbm_bytes=80e9,
    fabric=LinkModel("nvlink", 250e9, 12e-6),
    host_link=LinkModel("pcie4", 25e9, 10e-6),
)

# TPU v5e (target): constants from the brief.
TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    flops_peak=197e12,
    hbm_bw=819e9,
    hbm_bytes=16e9,
    fabric=LinkModel("ici", 50e9, 5e-6),
    host_link=LinkModel("pcie-host", 16e9, 20e-6),
)

PROFILES = {p.name: p for p in (A100_NVLINK, TPU_V5E)}


# ---------------------------------------------------------------------------
# Model-level cost formulas
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelCost:
    """Analytic per-model serving costs (dense-equivalent active params)."""
    n_params: float            # active parameters per token
    kv_bytes_per_token: float  # whole-stack KV bytes per cached token
    dtype_bytes: int = 2
    state_bytes: float = 0.0   # fixed recurrent state bytes per request
    #                            (RWKV wkv/shift, Mamba ssm/conv) — moved on
    #                            every context switch regardless of ctx_len
    n_layers: int = 1          # layers in the stack: each jitted serving
    #                            call issues ~one fused kernel launch per
    #                            layer (the launch-count model's unit)
    n_planes: int = 1          # page planes of the family's state layout
    #                            (kv=1, mla=1, rwkv wkv+shift=2, jamba
    #                            kv+ssm+conv=3) — the per-(tier,donor)
    #                            message count of an UNCOALESCED multi-plane
    #                            tier flip

    @staticmethod
    def from_config(cfg) -> "ModelCost":
        from repro.configs.base import ModelConfig  # noqa
        hd = cfg.resolved_head_dim
        if cfg.mla is not None:
            kvtok = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * cfg.n_layers * 2
        elif cfg.family == "ssm":
            kvtok = 0.0                      # O(1) state, no per-token cache
        else:
            n_attn = sum(1 for i in range(cfg.n_layers) if cfg.is_attention_layer(i))
            kvtok = 2 * cfg.n_kv_heads * hd * n_attn * 2
        # fixed recurrent state (the state page planes): f32 ssm/wkv + native
        # conv/shift leaves, per layer of the matching kind; n_planes mirrors
        # models/lm.py:paged_layout (the message count of an uncoalesced
        # multi-plane tier flip)
        state = 0.0
        n_planes = 1
        if cfg.family == "ssm" and cfg.ssm is not None:
            rhd = cfg.ssm.rwkv_head_dim
            H = cfg.d_model // rhd
            state = cfg.n_layers * (H * rhd * rhd * 4 + 2 * cfg.d_model * 2)
            n_planes = 2                          # wkv + shift
        elif cfg.family == "hybrid" and cfg.ssm is not None:
            s = cfg.ssm
            di = s.mamba_expand * cfg.d_model
            n_mamba = sum(1 for i in range(cfg.n_layers)
                          if not cfg.is_attention_layer(i))
            state = n_mamba * (di * s.mamba_d_state * 4
                               + (s.mamba_d_conv - 1) * di * 2)
            n_planes = 3                          # kv + ssm + conv
        n_active = cfg.param_count()
        if cfg.moe is not None:
            m = cfg.moe
            fe = m.d_ff_expert or cfg.d_ff
            glu = 3 if cfg.activation in ("swiglu", "geglu") else 2
            n_moe_layers = cfg.n_layers // m.moe_every
            inactive = (m.n_experts - m.top_k) * glu * cfg.d_model * fe * n_moe_layers
            n_active -= inactive
        return ModelCost(float(n_active), float(kvtok), state_bytes=float(state),
                         n_layers=int(cfg.n_layers), n_planes=int(n_planes))

    def prefill_time(self, hw: HardwareProfile, n_tokens: int) -> float:
        return 2.0 * self.n_params * n_tokens / (hw.flops_peak * hw.mfu)

    def launch_time(self, hw: HardwareProfile, n_calls: int) -> float:
        """Dispatch overhead of ``n_calls`` jitted serving calls: each call
        issues ~one fused kernel launch per layer of the stack. The
        per-request engine paid one call per admitted request's chunk plus
        one for decode — O(requests) launches per step; the fused step pays
        exactly one call."""
        return launch_overhead_time(hw, n_calls * self.n_layers)

    def decode_step_time(self, hw: HardwareProfile, batch: int,
                         ctx_tokens: float, weight_bytes: float) -> float:
        """One token for `batch` sequences with mean context `ctx_tokens`."""
        t_flops = 2.0 * self.n_params * batch / (hw.flops_peak * hw.mfu)
        kv_read = self.kv_bytes_per_token * ctx_tokens * batch
        t_mem = (weight_bytes + kv_read) / (hw.hbm_bw * hw.membw_util)
        return max(t_flops, t_mem)

    def fused_step_time(self, hw: HardwareProfile, batch: int,
                        ctx_tokens: float, weight_bytes: float,
                        chunk_tokens: int = 0) -> float:
        """One FUSED engine step: ``batch`` decode lanes plus
        ``chunk_tokens`` of prompt-chunk rows in the same launch per layer.

        The launches share one weight read: a decode step is memory-bound
        (weights + KV streaming dominate its roofline), so the chunk rows'
        FLOPs hide under that stream until they exceed it — prompt chunks
        PIGGYBACK on decode steps nearly for free instead of paying a
        separate launch sequence with its own weight pass. With
        ``chunk_tokens = 0`` this is exactly :meth:`decode_step_time`.
        """
        t_flops = (2.0 * self.n_params * (batch + chunk_tokens)
                   / (hw.flops_peak * hw.mfu))
        kv_read = self.kv_bytes_per_token * ctx_tokens * batch
        t_mem = (weight_bytes + kv_read) / (hw.hbm_bw * hw.membw_util)
        return max(t_flops, t_mem)

    def piggyback_tokens(self, hw: HardwareProfile, batch: int,
                         ctx_tokens: float, weight_bytes: float) -> int:
        """How many prompt-chunk tokens ride a decode launch FOR FREE.

        A decode step is memory-bound: its roofline floor is the weight +
        KV stream time ``t_mem``, while each token of compute costs only
        ``t_tok`` of FLOPs. Chunk tokens added to the fused launch hide
        under that stream until total FLOPs reach ``t_mem`` — the roofline
        crossover. This is the scheduler's slack budget: sizing
        ``split_step_budget`` chunks to it keeps mixed steps exactly AT the
        roofline instead of spilling past it (each extra token beyond the
        window extends the step linearly).
        """
        t_tok = 2.0 * self.n_params / (hw.flops_peak * hw.mfu)
        kv_read = self.kv_bytes_per_token * ctx_tokens * batch
        t_mem = (weight_bytes + kv_read) / (hw.hbm_bw * hw.membw_util)
        return max(int(t_mem / t_tok) - batch, 0)

    def kv_bytes(self, n_tokens: float) -> float:
        return self.kv_bytes_per_token * n_tokens

    def context_bytes(self, n_tokens: float) -> float:
        """Whole dynamic context of a request: token-paged KV/latents plus
        the fixed recurrent state pages — what one page-table tier flip
        moves on the unified paged runtime, for ANY family."""
        return self.kv_bytes(n_tokens) + self.state_bytes

    def unique_context_bytes(self, n_tokens: float,
                             shared_tokens: float = 0.0) -> float:
        """Dedup-aware context footprint: the bytes a request owns
        EXCLUSIVELY when its first ``shared_tokens`` of KV alias another
        resident request's pages (copy-on-write prefix sharing). The shared
        prefix is physical once per group — charge it to whichever sharer
        is counted first and price every other member (and their page-table
        tier flips while a sharer stays resident) at this marginal size."""
        return self.context_bytes(n_tokens) \
            - self.kv_bytes(min(shared_tokens, n_tokens))


def context_switch_time(hw: HardwareProfile, kv_bytes: float, *,
                        tier: str, coalesced: bool = True,
                        n_fragments: int = 1) -> float:
    """Time to page a prompt's context in or out via the BLOB path.

    tier: 'fabric' (AQUA: neighbor HBM over NVLink/ICI) or 'host' (DRAM/PCIe).
    coalesced=False models the naive path the paper measured first: one message
    per KV fragment (layer x page), which collapses to latency-bound transfers
    (Fig. 3a) — the motivation for the kv_gather kernel. coalesced=True still
    pays a full HBM pass to gather every cache leaf into the staging blob;
    ``page_flip_time`` below is the page-native runtime that doesn't.
    """
    link = hw.fabric if tier == "fabric" else hw.host_link
    msgs = max(1, n_fragments) if not coalesced else 1
    gather_overhead = kv_bytes / (hw.hbm_bw * hw.membw_util) if coalesced else 0.0
    return gather_overhead + link.time(kv_bytes, n_messages=msgs)


def launch_overhead_time(hw: HardwareProfile, n_launches: int) -> float:
    """Wall-time the host spends dispatching ``n_launches`` kernel launches.

    This is the per-step serving tax the fused engine step collapses: the
    per-request loop issued one jitted call per admitted request's chunk
    (plus one for decode), each ~one launch per layer, so dispatch overhead
    scaled with the number of admitted requests — the between-launch GPU
    idle regime of Kossmann et al. 2024. One fused call keeps it O(1).
    """
    return max(0, n_launches) * hw.launch_overhead


def retry_backoff_time(hw: HardwareProfile, attempt: int) -> float:
    """Backoff before re-issuing a failed transfer leg: exponential in the
    consecutive-failure count (attempt 1 waits ``retry_backoff``, attempt 2
    twice that, ...). Charged by ``TransferMeter.record_retry`` on top of
    the wasted message time; with the ``FaultInjector``'s streak cap the
    total per-leg penalty is bounded by a small constant."""
    return hw.retry_backoff * (2 ** max(int(attempt) - 1, 0))


def overlapped_transfer_time(compute_s: float, transfer_s: float) -> float:
    """VISIBLE wall-time of a page transfer overlapped with step compute.

    The paper's offload/compute overlap: page migrations are issued while the
    current iteration's kernels run, so the transfer is hidden up to the
    step's compute time and only the excess extends the step. This prices the
    engine's restore PREFETCH (``ensure_local`` for next-step scheduled
    requests issued during the current step) and the simulator's page-in leg.
    """
    return max(0.0, transfer_s - compute_s)


def page_flip_time(hw: HardwareProfile, payload_bytes: float, *,
                   tier: str, n_groups: int = 1) -> float:
    """Time to preempt/restore a request on the PAGE-NATIVE runtime.

    The decode cache already lives on pages, so a context switch is a
    page-table tier migration: no per-leaf gather, no float32 repack — just
    the native-dtype page payload moving as one coalesced message per
    (tier, donor) group (``n_groups``). This is what the paged ServingEngine
    meters, and what the simulator prices by default.
    """
    link = hw.fabric if tier == "fabric" else hw.host_link
    return link.time(payload_bytes, n_messages=max(1, n_groups))


def prefix_hit_saving(hw: HardwareProfile, model: ModelCost, *,
                      hit_tokens: int, tier: str = "fabric",
                      n_groups: int = 1) -> Tuple[float, float]:
    """Analytic ledger of ONE prefix-cache hit of ``hit_tokens`` tokens.

    Returns ``(prefill_time_saved, restore_time_paid)``: the hit skips the
    prefix's prefill FLOPs entirely, and pays instead one coalesced
    page-table tier flip bringing the cached prefix pages back LOCAL
    (``tier`` is where the cache's cold pages were demoted to — the fabric
    donor slabs or host DRAM; zero bytes when they are still LOCAL). A hit
    is a net win whenever saved > paid — for any non-trivial prefix the
    prefill side is compute over the whole model while the restore side is
    one link message of the prefix's KV bytes, so the crossover sits at a
    handful of tokens. The benchmark harness uses this to sanity-check the
    measured TTFT deltas in ``benchmarks/prefix_cache.py``.
    """
    saved = model.prefill_time(hw, int(hit_tokens))
    paid = page_flip_time(hw, model.kv_bytes(float(hit_tokens)),
                          tier=tier, n_groups=n_groups)
    return saved, paid


# ---------------------------------------------------------------------------
# Clock calibration: fit the alpha/beta link model to MEASURED transfers
# ---------------------------------------------------------------------------
def fit_link_model(samples: Sequence[Tuple[float, float]],
                   name: str) -> Optional[LinkModel]:
    """Least-squares fit of ``t = latency + nbytes / peak_bw`` to measured
    ``(nbytes, seconds)`` samples — the closing of the analytic clock's loop:
    ``page_flip_time`` and the ``TransferMeter`` keep their alpha + s/B form,
    but alpha and B become properties of THIS machine's fabric (MeshTierDomain
    wall-clocks every warm collective leg) instead of datasheet constants.

    Returns None when the samples cannot identify both parameters (fewer
    than 2 samples, or a single distinct message size — a vertical line fits
    any latency). Fitted latency is clamped to >= 0; a non-positive fitted
    slope (noise on a tiny size range) falls back to the effective bandwidth
    of the largest sample.
    """
    if len(samples) < 2:
        return None
    xs = np.asarray([s[0] for s in samples], np.float64)
    ys = np.asarray([s[1] for s in samples], np.float64)
    if len(np.unique(xs)) < 2:
        return None
    slope, alpha = np.polyfit(xs, ys, 1)
    if slope <= 0:
        big = int(np.argmax(xs))
        slope = ys[big] / xs[big] if xs[big] > 0 else None
        if not slope or slope <= 0:
            return None
    return LinkModel(name, float(1.0 / slope), float(max(alpha, 0.0)))


def calibrate_profile(hw: HardwareProfile, *,
                      fabric_samples: Optional[Sequence[Tuple[float, float]]] = None,
                      host_samples: Optional[Sequence[Tuple[float, float]]] = None,
                      min_samples: int = 4) -> HardwareProfile:
    """``hw`` with its link models replaced by fits to measured transfers.

    Each link is refit only when its sample set has at least ``min_samples``
    points AND the fit identifies both parameters; otherwise that link keeps
    its datasheet constants. With nothing to fit, returns ``hw`` unchanged
    (identity — callers can test ``is``-ness to detect calibration)."""
    fabric = hw.fabric
    host = hw.host_link
    if fabric_samples is not None and len(fabric_samples) >= min_samples:
        fabric = fit_link_model(fabric_samples,
                                f"{hw.fabric.name}-measured") or fabric
    if host_samples is not None and len(host_samples) >= min_samples:
        host = fit_link_model(host_samples,
                              f"{hw.host_link.name}-measured") or host
    if fabric is hw.fabric and host is hw.host_link:
        return hw
    return dataclasses.replace(hw, name=f"{hw.name}-calibrated",
                               fabric=fabric, host_link=host)
