"""AQUA-LIB control loops / informers (paper §3, §B).

The northbound interface between a serving engine and AQUA-LIB is
``inform_stats(...)``: the engine reports workload characteristics every few
iterations, and the return value tells the engine how much memory it may
reclaim for itself (positive) or should donate (negative).

  * ``LLMInformer``   — an LLM is a producer only while its traffic is low
                        (paper §B "llm-informer"): donates everything except a
                        small responsiveness reserve, reclaims on queue
                        build-up.
  * ``BatchInformer`` — compute-bound image/audio engines run at a fixed
                        peak-throughput batch size; everything beyond that
                        working set is donated ("<10 lines of code" in the
                        paper; about that many here).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional

from repro.core.coordinator import Coordinator


@dataclass
class InformerDecision:
    delta_bytes: float       # >0: engine may grow its cache; <0: donate -delta
    donate: bool
    reclaim: bool


class LLMInformer:
    def __init__(self, name: str, coordinator: Coordinator, *,
                 total_bytes: float, reserve_bytes: float = 5e9,
                 low_rate: float = 2.0, high_rate: float = 4.0,
                 window: int = 8):
        self.name = name
        self.coord = coordinator
        self.total = total_bytes
        self.reserve = reserve_bytes
        self.low, self.high = low_rate, high_rate
        self._pending: Deque[float] = deque(maxlen=window)
        self.donated = 0.0

    def inform_stats(self, pending_requests: int, kv_utilization: float,
                     dt: float = 1.0) -> InformerDecision:
        self._pending.append(pending_requests / max(dt, 1e-9))
        rate = sum(self._pending) / len(self._pending)
        if rate <= self.low and self.donated == 0.0 and kv_utilization < 0.5:
            amount = self.total - self.reserve
            self.coord.offer(self.name, amount)
            self.donated = amount
            return InformerDecision(-amount, donate=True, reclaim=False)
        if rate >= self.high and self.donated > 0.0:
            self.coord.request_reclaim(self.name)
            if self.coord.reclaim_status(self.name):
                got = self.donated
                self.donated = 0.0
                self.coord.withdraw(self.name)
                return InformerDecision(+got, donate=False, reclaim=True)
            return InformerDecision(0.0, donate=False, reclaim=True)
        return InformerDecision(0.0, donate=False, reclaim=False)


class BatchInformer:
    """Producer informer for compute-bound engines (image/audio)."""

    def __init__(self, name: str, coordinator: Coordinator, *,
                 total_bytes: float, working_set_bytes: float):
        self.name = name
        self.coord = coordinator
        self.free = total_bytes - working_set_bytes

    def inform_stats(self, *_args, **_kw) -> InformerDecision:
        if self.free > 0:
            self.coord.offer(self.name, self.free)
            donated, self.free = self.free, 0.0
            return InformerDecision(-donated, donate=True, reclaim=False)
        return InformerDecision(0.0, donate=False, reclaim=False)
