"""AQUA TENSORS — transparent, elastic, tiered paged tensors (paper §3).

A logical paged tensor whose pages physically live in one of three tiers:

  LOCAL   the serving chip's own HBM page pool (directly addressable by the
          paged_attention kernel)
  REMOTE  a *donor* chip's HBM pool, reachable over the scale-up fabric
          (NVLink in the paper; ICI here). Transfers are COALESCED: the
          kv_gather Pallas kernel packs the victim pages into one contiguous
          staging buffer, which moves as a single large message
          (distributed/collectives.paging_permute on a real mesh).
  HOST    host DRAM over PCIe — the FlexGen/vLLM-swap fallback tier the paper
          compares against.

The ML model is oblivious to placement (the paper's "transparent" property):
the serving engine only sees logical page ids; ``ensure_local`` is invoked at
inference-iteration boundaries (the paper's ``aqua.respond()`` insight — pages
are only read/written between iterations, so migration is race-free).

Serving-runtime hooks (docs/paged_runtime.md): the LOCAL pool is directly the
operand of the paged_attention kernels, ``block_tables`` answers batched
logical->physical LOCAL slot queries for whole request sets, and
``set_page_fill`` declares partial tails so a half-filled page is moved and
metered at its valid fraction only.

Elasticity: the remote tier is backed by *leases* from the coordinator; a
donor can reclaim its memory at any iteration boundary via ``evict_remote``.

Two REMOTE backends share one data path:

  single-device (mesh=None)   every tier is a real buffer on the serving
      device; transfers are gather -> staging -> scatter on one chip. Always
      available, bit-exact, and the reference the mesh backend is tested
      against.
  mesh-real (mesh=MeshTierDomain)   a donor lease is an actual slab of a
      PEER device's memory (distributed/mesh_tiers.py): the pool is sharded
      over the domain's 1-D mesh with the donor's rows resident on the donor
      device, and each (tier, donor) leg of offload/ensure_local/evict_remote
      lowers to ONE ``ppermute`` collective — physically matching the
      TransferMeter's one-message-per-leg pricing. Host staging exists only
      on the HOST leg.

Every movement is metered (bytes, messages, tier) and priced by
core/perfmodel.py — that is the simulated clock the benchmarks report; on a
mesh the clock is additionally CALIBRATED against measured collective times
(``MeshTierDomain.calibrated_profile``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import (AquaError, LeaseRevokedError, PageLossError,
                               TransferFaultError)
from repro.core.perfmodel import (HardwareProfile, TPU_V5E,
                                  retry_backoff_time)
from repro.kernels.kv_gather import ops as kv_ops

LOCAL, REMOTE, HOST = 0, 1, 2
# LOST: the page's only copy was on a donor that died (``fail_donor``).
# Lost pages keep their refcounts (the auditor still sees the referencers)
# but any read/migration raises PageLossError — the engine's recovery path
# releases the victims and recomputes their context from the prompt.
LOST = 3
TIER_NAMES = {LOCAL: "local", REMOTE: "remote", HOST: "host", LOST: "lost"}


@dataclass
class TransferMeter:
    """Accounting for every page movement; priced by the perf model.

    ``coalesce()`` opens a CROSS-PLANE transaction: every ``record`` inside
    it accumulates bytes per ``(tier, group)`` key instead of emitting a
    message, and the transaction emits ONE message per key on exit — the
    multi-plane park/restore of a request (kv + ssm + conv pages, say)
    rides one staging buffer per (tier, donor) instead of one message per
    plane, which is the AQUA Fig. 3a small-message tax applied to hybrid
    and SSM flips."""
    hw: HardwareProfile = TPU_V5E
    bytes_fabric: float = 0.0
    bytes_host: float = 0.0
    messages_fabric: int = 0
    messages_host: int = 0
    # failed-then-retried leg attempts (fault injection): priced like
    # messages plus backoff, but counted apart — a retry never issued a
    # physical collective
    retries_fabric: int = 0
    retries_host: int = 0
    sim_time: float = 0.0
    coalesced: bool = True
    _txn: Optional[Dict] = field(default=None, repr=False, compare=False)

    def record(self, nbytes: float, tier: int, n_pages: int, group=None):
        if self._txn is not None:
            b, p = self._txn.get((tier, group), (0.0, 0))
            self._txn[(tier, group)] = (b + nbytes, p + n_pages)
            return
        link = self.hw.fabric if tier == REMOTE else self.hw.host_link
        msgs = 1 if self.coalesced else max(1, n_pages)
        if tier == REMOTE:
            self.bytes_fabric += nbytes
            self.messages_fabric += msgs
        else:
            self.bytes_host += nbytes
            self.messages_host += msgs
        self.sim_time += link.time(nbytes, n_messages=msgs)

    def record_retry(self, nbytes: float, tier: int, n_pages: int,
                     attempt: int):
        """Price one FAILED transfer-leg attempt: the wasted message time
        plus exponential backoff before the retry. Retries bypass any open
        ``coalesce`` transaction (their time is real whatever the batching)
        and are counted in ``retries_*``, never ``messages_*`` — a failed
        attempt never issued a physical collective, so the mesh domain's
        ``collectives`` counter and the priced message count stay in
        lockstep."""
        link = self.hw.fabric if tier == REMOTE else self.hw.host_link
        msgs = 1 if self.coalesced else max(1, n_pages)
        if tier == REMOTE:
            self.retries_fabric += msgs
        else:
            self.retries_host += msgs
        self.sim_time += (link.time(nbytes, n_messages=msgs)
                          + retry_backoff_time(self.hw, attempt))

    def coalesce(self):
        """Context manager fusing every ``record`` inside it into one
        message per ``(tier, group)`` key (reentrant: the outermost
        transaction wins)."""
        return _MeterTxn(self)


class _MeterTxn:
    def __init__(self, meter: TransferMeter):
        self.meter = meter
        self.outer = False

    def __enter__(self):
        if self.meter._txn is not None:
            self.outer = True           # nested: fold into the outer txn
            return self.meter
        self.meter._txn = {}
        return self.meter

    def __exit__(self, exc_type, exc, tb):
        if self.outer:
            return False
        txn, self.meter._txn = self.meter._txn, None
        for (tier, _group), (nbytes, n_pages) in txn.items():
            self.meter.record(nbytes, tier, n_pages)
        return False


class AquaTensor:
    """A paged tensor with tiered page placement. Page payload: (page, d)."""

    def __init__(self, *, n_logical: int, page_shape: Tuple[int, ...],
                 local_slots: int, host_slots: int, dtype=jnp.bfloat16,
                 meter: Optional[TransferMeter] = None, name: str = "kv",
                 mesh=None, faults=None):
        self.name = name
        # optional MeshTierDomain: REMOTE pools become donor-device slabs and
        # remote legs become collectives (duck-typed; None = single-device)
        self.mesh = mesh
        # optional core/faults.FaultInjector, consulted at every transfer
        # leg (bounded retry-with-backoff on transient failures) and lease
        # boundary (lost donors are never addressed again)
        self.faults = faults
        self.page_shape = tuple(page_shape)
        self.dtype = jnp.dtype(dtype)
        self.page_bytes = int(np.prod(page_shape)) * self.dtype.itemsize
        self.local_pool = jnp.zeros((local_slots,) + self.page_shape, self.dtype)
        self.host_pool = np.zeros((host_slots,) + self.page_shape, self.dtype)
        self.remote_pools: Dict[str, jnp.ndarray] = {}
        self._remote_free: Dict[str, List[int]] = {}
        # page_table[lp] = (tier, slot, donor_idx) ; -1 = unallocated
        self.page_table = np.full((n_logical, 3), -1, np.int64)
        # reference count per logical page: pages shared between block tables
        # (prefix sharing) are retained once per referencer and their physical
        # slot is released only when the LAST reference is freed. All physical
        # accounting (tier_counts, local_free, MemoryError on exhaustion) is
        # per logical page, so a page shared by N block tables costs one slot.
        self.page_refs = np.zeros((n_logical,), np.int64)
        # fraction of the page payload that holds live data (partial tails):
        # transfers are metered on valid bytes only, so a request's last,
        # half-filled KV page does not inflate its migration cost.
        self.page_fill = np.ones((n_logical,), np.float64)
        self._free_local = list(range(local_slots))[::-1]
        self._free_host = list(range(host_slots))[::-1]
        self._donors: List[str] = []
        # leased slots per live donor (shrinks under ``shrink_lease``) — the
        # capacity the auditor checks the free-list/occupancy partition
        # against
        self.remote_capacity: Dict[str, int] = {}
        self.meter = meter or TransferMeter()
        # CACHED pages: refcount 0 but still physically resident (a prefix
        # cache retains them for future adoption). ``reclaim`` is an optional
        # hook ``reclaim(tier, need) -> freed`` installed by the cache owner;
        # it is consulted when a tier's free list runs dry so cached pages
        # YIELD before any real allocation can fail — a cache-on run never
        # raises a MemoryError a cache-off run would not hit.
        self.reclaim = None
        self._reclaiming = False

    def _try_reclaim(self, tier: int, need: int) -> int:
        """Ask the cache owner to evict/demote cached pages out of ``tier``.
        Reentrancy-guarded: an eviction's own demotion ``_move`` must not
        recurse into another reclaim."""
        if self.reclaim is None or self._reclaiming:
            return 0
        self._reclaiming = True
        try:
            return int(self.reclaim(tier, need))
        finally:
            self._reclaiming = False

    # ------------------------------------------------------------------
    # lease management (driven by the coordinator)
    # ------------------------------------------------------------------
    def add_remote_lease(self, donor: str, slots: int):
        """Donor offered `slots` pages of its HBM (coordinator /lease).

        A donor evicted earlier may re-lease: its ``_donors`` entry is
        REUSED, never duplicated — a second append would leave the old
        index resolvable to the new pool for any stale ``donor_idx`` and
        split one physical donor across two bookkeeping identities.

        Raises:
            ValueError: the donor already holds a live lease here.
            LeaseRevokedError: the donor was marked permanently lost.
        """
        if donor in self.remote_pools:
            raise ValueError(f"{self.name}: donor {donor} already holds a "
                             "live lease (evict before re-leasing)")
        if self.faults is not None and self.faults.donor_lost(donor):
            raise LeaseRevokedError(
                f"{self.name}: donor {donor} is permanently lost and cannot "
                "offer a lease", donor=donor)
        if self.mesh is not None:
            self.remote_pools[donor] = self.mesh.alloc_pool(
                donor, slots, self.page_shape, self.dtype)
        else:
            self.remote_pools[donor] = jnp.zeros(
                (slots,) + self.page_shape, self.dtype)
        self._remote_free[donor] = list(range(slots))[::-1]
        self.remote_capacity[donor] = int(slots)
        if donor not in self._donors:
            self._donors.append(donor)

    def evict_remote(self, donor: str) -> int:
        """Donor reclaims its lease: evacuate pages to host, drop the pool."""
        moved = 0
        victims = np.nonzero((self.page_table[:, 0] == REMOTE)
                             & (self.page_table[:, 2] == self._donors.index(donor)))[0]
        if len(victims):
            self._move(victims, HOST)
            moved = len(victims)
        del self.remote_pools[donor]
        del self._remote_free[donor]
        del self.remote_capacity[donor]
        # donor stays in _donors so indices of others remain stable
        return moved

    def shrink_lease(self, donor: str, n_slots: int) -> int:
        """Donor reclaims its TOP ``n_slots`` slots under its own memory
        pressure (the dynamic-lease gap: eviction's partial form). Occupied
        reclaimed slots LIVE-MIGRATE to the remaining remote donors or the
        HOST tier — never back onto the shrinking donor (it wants the HBM
        back, re-placing there would hand it straight out again). Reclaimed
        free slots just leave the free list. A shrink to zero drops the
        lease entirely (like ``evict_remote``). Returns pages migrated.

        Raises:
            LeaseRevokedError: no live lease from this donor.
            MemoryError: the surviving tiers cannot absorb the migration.
        """
        if donor not in self.remote_pools:
            raise LeaseRevokedError(
                f"{self.name}: shrink of donor {donor} without a live lease",
                donor=donor)
        cap = self.remote_capacity[donor]
        n = int(min(max(n_slots, 0), cap))
        if n == 0:
            return 0
        lo = cap - n
        di = self._donors.index(donor)
        victims = np.nonzero((self.page_table[:, 0] == REMOTE)
                             & (self.page_table[:, 2] == di)
                             & (self.page_table[:, 1] >= lo))[0]
        moved = 0
        if len(victims):
            self._move(victims, REMOTE, exclude_donor=donor)
            moved = len(victims)
        self._remote_free[donor] = [s for s in self._remote_free[donor]
                                    if s < lo]
        self.remote_capacity[donor] = lo
        if lo == 0:
            del self.remote_pools[donor]
            del self._remote_free[donor]
            del self.remote_capacity[donor]
        return moved

    def fail_donor(self, donor: str) -> np.ndarray:
        """Permanent donor loss: the peer died holding its slab, so every
        page resident there is gone — no evacuation leg exists to run. The
        pages flip to the LOST tier (refcounts intact: the auditor still
        sees every referencer until recovery releases them) and the lease
        is dropped. Returns the lost logical page ids; reading, migrating,
        or building block tables over them raises ``PageLossError`` — the
        engine's cue to re-queue the victims and recompute from the
        prompt."""
        if donor not in self.remote_pools:
            return np.zeros((0,), np.int64)
        di = self._donors.index(donor)
        lost = np.nonzero((self.page_table[:, 0] == REMOTE)
                          & (self.page_table[:, 2] == di))[0]
        self.page_table[lost, 0] = LOST
        del self.remote_pools[donor]
        del self._remote_free[donor]
        del self.remote_capacity[donor]
        if self.faults is not None:
            self.faults.mark_donor_lost(donor)
        return lost

    # ------------------------------------------------------------------
    # allocation
    # ------------------------------------------------------------------
    def allocate(self, n: int, prefer: int = LOCAL) -> np.ndarray:
        """Allocate n logical pages (preferred tier first, then fallbacks).

        Each page starts with refcount 1 (the allocator owns it); sharers
        call :meth:`retain` to add references.

        Raises:
            MemoryError: out of logical page ids, or every physical tier is
                full (``all tiers full``).
        """
        free_lp = np.nonzero(self.page_table[:, 0] == -1)[0]
        if len(free_lp) < n:
            # cached pages occupy logical ids too: ask them to yield
            # (tier -1 = "free outright, any tier") before failing
            self._try_reclaim(-1, n - len(free_lp))
            free_lp = np.nonzero(self.page_table[:, 0] == -1)[0]
        if len(free_lp) < n:
            raise MemoryError(f"{self.name}: out of logical pages")
        lps = free_lp[:n]
        taken: List[int] = []
        try:
            for lp in lps:
                tier, slot, donor = self._take_slot(prefer)
                self.page_table[lp] = (tier, slot, donor)
                taken.append(int(lp))
        except MemoryError:
            # all-or-nothing: hand back every slot this call already took —
            # a partial multi-page allocation must not leak pages when the
            # pool runs dry mid-way
            self._release_slots(taken)
            raise
        self.page_fill[lps] = 1.0
        self.page_refs[lps] = 1
        return lps

    def _release_slots(self, lps: Sequence[int]):
        """Return the physical slots of not-yet-reffed pages to their free
        lists (allocation-rollback helper: the pages were taken in a failing
        call and never exposed to a caller)."""
        for lp in lps:
            tier, slot, donor = self.page_table[lp]
            if tier == LOCAL:
                self._free_local.append(int(slot))
            elif tier == HOST:
                self._free_host.append(int(slot))
            elif tier == REMOTE:
                self._remote_free[self._donors[donor]].append(int(slot))
            self.page_table[lp] = (-1, -1, -1)
            self.page_fill[lp] = 1.0
            self.page_refs[lp] = 0

    def retain(self, lps: Sequence[int]):
        """Add one reference to each listed page (copy-on-write sharing): the
        physical slot is released only when every reference is freed."""
        lps = np.asarray(lps, np.int64)
        if (self.page_refs[lps] < 1).any():
            bad = [int(l) for l in lps if self.page_refs[l] < 1]
            raise ValueError(f"{self.name}: retain of unallocated pages {bad}")
        self.page_refs[lps] += 1

    def refcounts(self, lps: Sequence[int]) -> np.ndarray:
        """Current reference count of each listed logical page."""
        return self.page_refs[np.asarray(lps, np.int64)].copy()

    def free(self, lps: Sequence[int]) -> List[int]:
        """Drop one reference per listed page; release the physical slot of
        pages whose count reaches zero. Returns the logical ids actually
        freed — a page still referenced by another block table survives with
        its payload intact (the sharer keeps reading it)."""
        freed: List[int] = []
        for lp in lps:
            if self.page_refs[lp] > 1:
                self.page_refs[lp] -= 1
                continue
            tier, slot, donor = self.page_table[lp]
            if tier == LOCAL:
                self._free_local.append(int(slot))
            elif tier == HOST:
                self._free_host.append(int(slot))
            elif tier == REMOTE:
                self._remote_free[self._donors[donor]].append(int(slot))
            # LOST: the slot's pool is gone — nothing to hand back
            self.page_table[lp] = (-1, -1, -1)
            self.page_fill[lp] = 1.0
            self.page_refs[lp] = 0
            freed.append(int(lp))
        return freed

    # ------------------------------------------------------------------
    # CACHED state: refcount 0, still resident (global prefix cache)
    # ------------------------------------------------------------------
    def free_to_cache(self, lps: Sequence[int]) -> List[int]:
        """Drop one reference per listed page but KEEP the physical slot of
        pages whose count reaches zero — they enter the CACHED state
        (refcount 0, page_table row still valid, payload intact) so a future
        prefix adoption can ``revive`` them instead of recomputing prefill.
        Returns the logical ids that just became cached. A LOST page cannot
        be cached (its payload is gone): it is freed as usual."""
        cached: List[int] = []
        for lp in lps:
            if self.page_refs[lp] > 1:
                self.page_refs[lp] -= 1
                continue
            if self.page_table[lp, 0] == LOST:
                self.page_table[lp] = (-1, -1, -1)
                self.page_fill[lp] = 1.0
                self.page_refs[lp] = 0
                continue
            self.page_refs[lp] = 0
            cached.append(int(lp))
        return cached

    def revive(self, lps: Sequence[int]):
        """Cache hit: take the first reference on CACHED pages (refcount
        0 -> 1). Strict counterpart of :meth:`retain`, which refuses
        refcount-0 pages — revive refuses anything NOT cached."""
        lps = np.asarray(lps, np.int64)
        bad = [int(l) for l in lps
               if self.page_refs[l] != 0 or self.page_table[l, 0] == -1]
        if bad:
            raise ValueError(f"{self.name}: revive of non-cached pages {bad}")
        self.page_refs[lps] = 1

    def drop_cached(self, lps: Sequence[int]) -> List[int]:
        """Evict CACHED pages: hand their physical slots back to the free
        lists (LOST rows have no pool — just clear the row). Only legal on
        refcount-0 resident pages; returns the ids actually dropped."""
        dropped: List[int] = []
        for lp in lps:
            if self.page_refs[lp] != 0 or self.page_table[lp, 0] == -1:
                raise ValueError(
                    f"{self.name}: drop_cached of non-cached page {int(lp)}")
            tier, slot, donor = self.page_table[lp]
            if tier == LOCAL:
                self._free_local.append(int(slot))
            elif tier == HOST:
                self._free_host.append(int(slot))
            elif tier == REMOTE:
                self._remote_free[self._donors[donor]].append(int(slot))
            self.page_table[lp] = (-1, -1, -1)
            self.page_fill[lp] = 1.0
            dropped.append(int(lp))
        return dropped

    def set_page_fill(self, lps: Sequence[int], frac):
        """Declare the valid fraction of each page payload (partial tails)."""
        self.page_fill[np.asarray(lps, np.int64)] = np.clip(frac, 0.0, 1.0)

    def _take_slot(self, prefer: int = LOCAL) -> Tuple[int, int, int]:
        order = {LOCAL: [LOCAL, REMOTE, HOST], REMOTE: [REMOTE, HOST, LOCAL],
                 HOST: [HOST, REMOTE, LOCAL]}[prefer]
        for tier in order:
            if tier == LOCAL:
                if not self._free_local:
                    self._try_reclaim(LOCAL, 1)
                if self._free_local:
                    return LOCAL, self._free_local.pop(), -1
            if tier == REMOTE:
                for di, d in enumerate(self._donors):
                    if d in self._remote_free and self._remote_free[d]:
                        return REMOTE, self._remote_free[d].pop(), di
            if tier == HOST:
                if not self._free_host:
                    self._try_reclaim(HOST, 1)
                if self._free_host:
                    return HOST, self._free_host.pop(), -1
        raise MemoryError(f"{self.name}: all tiers full")

    # ------------------------------------------------------------------
    # remote-pool transfer legs (mesh-aware, fault-guarded)
    # ------------------------------------------------------------------
    def _leg_guard(self, tier: int, donor: Optional[str], n_pages: int):
        """Consult the fault injector BEFORE issuing a transfer leg.

        Transient failures retry with exponential backoff, each failed
        attempt priced as a full wasted message (``record_retry``); the
        injector's ``max_consecutive`` streak cap guarantees convergence
        below the retry budget for any seed. Because the consult precedes
        the collective, a failed attempt never reaches the wire — the mesh
        ``collectives`` counter stays in lockstep with priced messages.

        Raises:
            LeaseRevokedError: the addressed donor is permanently lost.
            TransferFaultError: the leg failed past ``max_leg_retries``
                (unreachable with a streak-capped injector).
        """
        f = self.faults
        if f is None:
            return
        if f.donor_lost(donor):
            raise LeaseRevokedError(
                f"{self.name}: transfer leg addressed lost donor {donor}",
                donor=donor)
        nbytes = float(n_pages) * self.page_bytes
        attempt = 0
        while f.leg_fails(tier, donor):
            attempt += 1
            self.meter.record_retry(nbytes, tier, n_pages, attempt)
            if attempt >= f.max_leg_retries:
                raise TransferFaultError(
                    f"{self.name}: {TIER_NAMES[tier]} leg"
                    f"{' to ' + donor if donor else ''} failed "
                    f"{attempt} consecutive attempts (retry budget "
                    f"{f.max_leg_retries})", tier=tier, donor=donor,
                    attempts=attempt)

    def _remote_gather(self, donor: str, slots) -> jnp.ndarray:
        """Pull `slots` out of a donor pool as one contiguous staging batch.
        Mesh backend: one ``ppermute`` donor -> serving device."""
        if donor not in self.remote_pools:
            raise LeaseRevokedError(
                f"{self.name}: gather from donor {donor} without a live "
                "lease", donor=donor)
        self._leg_guard(REMOTE, donor, len(slots))
        pool = self.remote_pools[donor]
        slots = np.asarray(slots, np.int32)
        if self.mesh is not None:
            return self.mesh.pull(pool, donor, slots)
        return kv_ops.gather_pages(pool, jnp.asarray(slots))

    def _remote_scatter(self, donor: str, slots, data: jnp.ndarray):
        """Push a contiguous staging batch into a donor pool at `slots`.
        Mesh backend: one ``ppermute`` serving device -> donor."""
        if donor not in self.remote_pools:
            raise LeaseRevokedError(
                f"{self.name}: scatter to donor {donor} without a live "
                "lease", donor=donor)
        self._leg_guard(REMOTE, donor, len(slots))
        pool = self.remote_pools[donor]
        slots = np.asarray(slots, np.int32)
        data = data.astype(self.dtype)
        if self.mesh is not None:
            self.remote_pools[donor] = self.mesh.push(pool, donor, slots, data)
        else:
            self.remote_pools[donor] = kv_ops.scatter_pages(
                pool, data, jnp.asarray(slots))

    # ------------------------------------------------------------------
    # data access
    # ------------------------------------------------------------------
    def write_local(self, lps: Sequence[int], data: jnp.ndarray):
        """Write page payloads for LOCAL-resident logical pages."""
        slots = self._slots_of(lps, LOCAL)
        self.local_pool = kv_ops.scatter_pages(
            self.local_pool, data.astype(self.dtype), jnp.asarray(slots, jnp.int32))

    def write(self, lps: Sequence[int], data: jnp.ndarray, *, meter: bool = True):
        """Write page payloads wherever the pages live. Non-local groups are
        one coalesced transfer each (metered): data is already contiguous, so
        this is the staging-buffer -> donor/host leg of a page-out."""
        data = data.astype(self.dtype)
        rows = self.page_table[np.asarray(lps, np.int64)]
        for tier in (LOCAL, REMOTE, HOST):
            idx = np.nonzero(rows[:, 0] == tier)[0]
            if not len(idx):
                continue
            slots = rows[idx, 1].astype(np.int32)
            part = data[idx]
            if tier == LOCAL:
                self.local_pool = kv_ops.scatter_pages(
                    self.local_pool, part, jnp.asarray(slots))
                continue
            if tier == REMOTE:
                for di in np.unique(rows[idx, 2]):
                    sub = idx[rows[idx, 2] == di]
                    d = self._donors[int(di)]
                    self._remote_scatter(d, rows[sub, 1], data[sub])
                    if meter:
                        self.meter.record(data[sub].nbytes, REMOTE, len(sub))
            else:
                self._leg_guard(HOST, None, len(idx))
                self.host_pool[slots] = np.asarray(part)
                if meter:
                    self.meter.record(part.nbytes, HOST, len(idx))

    def read(self, lps: Sequence[int], *, meter: bool = False) -> jnp.ndarray:
        """Gather page payloads regardless of tier (does not migrate).
        Batched per (tier, donor) group — one gather (one collective, on a
        mesh) per group, reassembled into request order. meter=True prices
        the non-local groups as coalesced page-in transfers (the restore leg
        of a context switch)."""
        lps = np.asarray(lps, np.int64)
        rows = self.page_table[lps]
        if len(lps) == 0:
            return jnp.zeros((0,) + self.page_shape, self.dtype)
        self._check_not_lost(lps, rows, "read")
        parts: List[jnp.ndarray] = []
        order: List[np.ndarray] = []
        for tier in (LOCAL, REMOTE, HOST):
            idx = np.nonzero(rows[:, 0] == tier)[0]
            if not len(idx):
                continue
            if tier == LOCAL:
                parts.append(self.local_pool[jnp.asarray(
                    rows[idx, 1].astype(np.int32))])
                order.append(idx)
            elif tier == HOST:
                self._leg_guard(HOST, None, len(idx))
                parts.append(jnp.asarray(
                    self.host_pool[rows[idx, 1].astype(np.int64)]))
                order.append(idx)
            else:
                for di in np.unique(rows[idx, 2]):
                    sub = idx[rows[idx, 2] == di]
                    parts.append(self._remote_gather(
                        self._donors[int(di)], rows[sub, 1]))
                    order.append(sub)
        combined = jnp.concatenate(parts, axis=0)
        positions = np.concatenate(order)
        out = combined[jnp.asarray(np.argsort(positions, kind="stable"))]
        if meter:
            fills = self.page_fill[lps]
            for tier in (REMOTE, HOST):
                idx = np.nonzero(rows[:, 0] == tier)[0]
                if len(idx):
                    self.meter.record(float(fills[idx].sum()) * self.page_bytes,
                                      tier, len(idx))
        return out

    def local_slots_of(self, lps: Sequence[int]) -> np.ndarray:
        return self._slots_of(lps, LOCAL)

    def block_tables(self, lps_rows: Sequence[Sequence[int]], pad_to: int,
                     *, pad_slot: int = 0) -> np.ndarray:
        """Batched block-table query: physical LOCAL slots of each row's
        logical pages as one padded (B, pad_to) int32 table — the operand the
        paged_attention kernel consumes. Every listed page must be LOCAL
        (call ``ensure_local`` first); padding entries point at ``pad_slot``
        (a resident dummy) so masked DMAs stay in-bounds."""
        out = np.full((len(lps_rows), pad_to), pad_slot, np.int32)
        for b, lps in enumerate(lps_rows):
            if len(lps) == 0:
                continue
            if len(lps) > pad_to:
                raise ValueError(f"{self.name}: row {b} has {len(lps)} pages"
                                 f" > pad_to={pad_to}")
            rows = self.page_table[np.asarray(lps, np.int64)]
            if not (rows[:, 0] == LOCAL).all():
                self._check_not_lost(lps, rows, "block-table build")
                bad = [int(l) for l, r in zip(lps, rows) if r[0] != LOCAL]
                raise ValueError(f"{self.name}: pages {bad} not LOCAL; "
                                 "ensure_local before building block tables")
            out[b, :len(lps)] = rows[:, 1]
        return out

    def _slots_of(self, lps, tier) -> np.ndarray:
        rows = self.page_table[np.asarray(lps, np.int64)]
        if not (rows[:, 0] == tier).all():
            bad = [int(l) for l, r in zip(lps, rows) if r[0] != tier]
            raise ValueError(f"pages {bad} not in tier {TIER_NAMES[tier]}")
        return rows[:, 1].astype(np.int32)

    # ------------------------------------------------------------------
    # migration (the AQUA mechanism)
    # ------------------------------------------------------------------
    def ensure_local(self, lps: Sequence[int]):
        """Page-in: make all listed logical pages LOCAL (coalesced per tier).

        Raises:
            PageLossError: a listed page is in the LOST tier (its donor died
                holding the only copy) — there is nothing to page in.
        """
        lps = np.asarray(lps, np.int64)
        rows = self.page_table[lps]
        self._check_not_lost(lps, rows, "ensure_local")
        for tier in (REMOTE, HOST):
            sel = lps[rows[:, 0] == tier]
            if len(sel):
                self._move(sel, LOCAL)

    def offload(self, lps: Sequence[int], *, prefer: int = REMOTE):
        """Page-out LOCAL pages to the fast remote tier (host as fallback).

        Raises:
            PageLossError: a listed page is LOST — silently skipping it
                (like the already-remote pages below) would mask a donor
                death from the park path.
        """
        lps = np.asarray(lps, np.int64)
        rows = self.page_table[lps]
        self._check_not_lost(lps, rows, "offload")
        sel = lps[rows[:, 0] == LOCAL]
        if len(sel):
            self._move(sel, prefer)

    def _check_not_lost(self, lps, rows, op: str):
        """Touching a LOST page is unrecoverable here — surface the typed
        loss so the engine's recompute-from-prompt path takes over."""
        lost = [int(l) for l, r in zip(lps, rows) if r[0] == LOST]
        if lost:
            raise PageLossError(
                f"{self.name}: {op} of page(s) {lost[:8]} whose donor died "
                "holding the only copy", plane=self.name, pages=lost)

    def _move(self, lps: np.ndarray, dst_tier: int,
              exclude_donor: Optional[str] = None):
        """Coalesced migration of a batch of pages between tiers.

        ``exclude_donor`` removes one donor from the REMOTE destination set
        (a shrinking donor must not receive the pages it is reclaiming).

        Raises:
            PageLossError: a listed page is in the LOST tier.
        """
        # group by (source tier, donor) so each group is ONE gather + transfer
        rows = self.page_table[lps]
        self._check_not_lost(lps, rows, "migration")
        groups: Dict[Tuple[int, int], List[int]] = {}
        for lp, (tier, slot, donor) in zip(lps, rows):
            groups.setdefault((int(tier), int(donor)), []).append(int(lp))
        for (src_tier, src_donor), group in groups.items():
            slots = self.page_table[group, 1].astype(np.int32)
            # 1) coalescing gather into a contiguous staging buffer. The
            # source slots are NOT freed yet: destination acquisition below
            # can fail (tier exhausted even after cache reclaim), and the
            # group's rows must still be valid then — freeing first left
            # pages mapped to free-listed slots, a double-free on their
            # eventual release.
            if src_tier == LOCAL:
                staging = kv_ops.gather_pages(self.local_pool, jnp.asarray(slots))
            elif src_tier == REMOTE:
                donor_name = self._donors[src_donor]
                staging = self._remote_gather(donor_name, slots)
            else:
                self._leg_guard(HOST, None, len(slots))
                staging = jnp.asarray(self.host_pool[slots])
            # valid payload only: a partial tail page moves (and is priced
            # as) its live rows, not the whole page buffer
            fills = self.page_fill[group] * self.page_bytes   # per-page bytes
            # 2) message metering rides the placement below: the txn key is
            # (src tier, src donor NAME, dst tier, dst donor NAME), so a
            # cross-plane coalesce() transaction fuses every plane's leg of
            # the same physical migration into one staging buffer per
            # (tier, donor) — donor NAMES, not per-plane indices (two
            # planes may hold different donor lists when a lease's share
            # rounds to zero), and transfers touching different physical
            # donors on EITHER end never fuse into one message
            transfer_tier = REMOTE if (src_tier == REMOTE or dst_tier == REMOTE) else HOST
            src_name = self._donors[src_donor] if src_donor >= 0 else None

            def meter(lo, hi, dst, dst_name):
                if dst_tier == src_tier or hi <= lo:
                    return
                self.meter.record(float(fills[lo:hi].sum()), transfer_tier,
                                  hi - lo,
                                  group=(src_tier, src_name, dst, dst_name))

            # 3) acquire destination slots and scatter (metering per
            # destination donor group). A failure mid-placement (tier
            # exhausted past reclaim, or a transfer leg dying) rolls every
            # acquired slot back: the group's source rows stay
            # authoritative, so the caller sees the exception against an
            # unchanged page table and free lists.
            new_rows = []
            popped: List[Tuple[List[int], int]] = []
            try:
                if dst_tier == LOCAL:
                    dst_slots = [self._pop_free(self._free_local, LOCAL,
                                                len(group))
                                 for _ in group]
                    popped += [(self._free_local, s) for s in dst_slots]
                    self.local_pool = kv_ops.scatter_pages(
                        self.local_pool, staging,
                        jnp.asarray(dst_slots, jnp.int32))
                    new_rows = [(LOCAL, s, -1) for s in dst_slots]
                    meter(0, len(group), LOCAL, None)
                elif dst_tier == REMOTE:
                    placed = 0
                    for di, d in enumerate(self._donors):
                        if d == exclude_donor:
                            continue
                        free = self._remote_free.get(d, [])
                        take = min(len(free), len(group) - placed)
                        if take <= 0:
                            continue
                        dst_slots = [free.pop() for _ in range(take)]
                        popped += [(free, s) for s in dst_slots]
                        self._remote_scatter(d, dst_slots,
                                             staging[placed:placed + take])
                        new_rows += [(REMOTE, s, di) for s in dst_slots]
                        meter(placed, placed + take, REMOTE, d)
                        placed += take
                    if placed < len(group):      # remote full -> host fallback
                        rest = staging[placed:]
                        need = len(group) - placed
                        self._leg_guard(HOST, None, need)
                        dst_slots = [self._pop_free(self._free_host, HOST,
                                                    need)
                                     for _ in range(need)]
                        popped += [(self._free_host, s) for s in dst_slots]
                        self.host_pool[np.asarray(dst_slots)] = np.asarray(rest)
                        new_rows += [(HOST, s, -1) for s in dst_slots]
                        meter(placed, len(group), HOST, None)
                else:
                    self._leg_guard(HOST, None, len(group))
                    dst_slots = [self._pop_free(self._free_host, HOST,
                                                len(group))
                                 for _ in group]
                    popped += [(self._free_host, s) for s in dst_slots]
                    self.host_pool[np.asarray(dst_slots)] = np.asarray(staging)
                    new_rows = [(HOST, s, -1) for s in dst_slots]
                    meter(0, len(group), HOST, None)
            except (MemoryError, AquaError):
                # every intentional failure class a placement can hit:
                # _pop_free exhaustion past reclaim (MemoryError) and
                # _leg_guard transfer faults / lease revocations (AquaError)
                for free_list, s in popped:
                    free_list.append(s)
                raise
            # 4) the whole group landed: only now do the source slots
            # return to their free lists and the rows repoint
            if src_tier == LOCAL:
                for s in slots:
                    self._free_local.append(int(s))
            elif src_tier == REMOTE:
                for s in slots:
                    self._remote_free[src_name].append(int(s))
            else:
                for s in slots:
                    self._free_host.append(int(s))
            for lp, row in zip(group, new_rows):
                self.page_table[lp] = row

    def _pop_free(self, free_list: List[int], tier: int, need: int) -> int:
        """Take one destination slot, or fail loudly: a bare IndexError from
        ``list.pop`` told the operator nothing about which tensor/tier ran dry
        (e.g. ``evict_remote`` onto an already-full host pool). Before
        failing, cached (refcount-0) pages in the tier are asked to yield."""
        if not free_list:
            self._try_reclaim(tier, need)
        if not free_list:
            raise MemoryError(
                f"{self.name}: {TIER_NAMES[tier]} tier exhausted while "
                f"migrating pages (needed {need} free slot(s))")
        return free_list.pop()

    # ------------------------------------------------------------------
    def tier_counts(self) -> Dict[str, int]:
        t = self.page_table[:, 0]
        out = {TIER_NAMES[k]: int((t == k).sum())
               for k in (LOCAL, REMOTE, HOST)}
        n_lost = int((t == LOST).sum())
        if n_lost:                    # only surfaced while a loss is live
            out["lost"] = n_lost
        return out

    @property
    def local_free(self) -> int:
        return len(self._free_local)

    @property
    def remote_free(self) -> int:
        return sum(len(v) for v in self._remote_free.values())
