"""AQUA central coordinator (paper §3).

A thread-safe registry of HBM *producers* (chips with spare memory) and
*consumers* (chips running memory-bound inference). The paper exposes this as
REST endpoints on a coordinator process; here the same surface is a
thread-safe object — the methods map 1:1 onto the paper's endpoints:

    /lease            -> offer(producer, bytes)
    /allocate         -> allocate(consumer, bytes)   (returns donor grants)
    /free             -> free(consumer, donor, bytes)
    /reclaim_request  -> request_reclaim(producer)
    /respond          -> pending_reclaims(consumer)  (polled at iteration
                         boundaries by the consumer control loop)
    /reclaim_status   -> reclaim_status(producer)

AQUA-PLACER pre-pairs each consumer with exactly one producer (one-to-one, so
a donor's fabric bandwidth is never shared — paper §4); the coordinator
enforces the pairing but also supports opportunistic many-to-many grants for
clusters run without the placer (flag ``strict_pairing=False``).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Offer:
    producer: str
    total_bytes: float
    granted_bytes: float = 0.0
    reclaim_requested: bool = False

    @property
    def available(self) -> float:
        return 0.0 if self.reclaim_requested else self.total_bytes - self.granted_bytes


@dataclass
class Grant:
    consumer: str
    producer: str
    nbytes: float
    released: bool = False


class Coordinator:
    def __init__(self, *, strict_pairing: bool = True):
        self._lock = threading.Lock()
        self._offers: Dict[str, Offer] = {}
        self._grants: List[Grant] = []
        self._pairing: Dict[str, str] = {}      # consumer -> producer
        self.strict_pairing = strict_pairing

    # -- placement ------------------------------------------------------
    def set_pairing(self, pairs: Dict[str, str]):
        """Install AQUA-PLACER's consumer->producer matching."""
        with self._lock:
            self._pairing = dict(pairs)

    # -- producer side ----------------------------------------------------
    def offer(self, producer: str, nbytes: float):
        """Producer leases `nbytes` of its HBM to the pool (/lease)."""
        with self._lock:
            o = self._offers.get(producer)
            if o is None:
                self._offers[producer] = Offer(producer, nbytes)
            else:
                # re-offer replaces the lease size (never below what is granted)
                o.total_bytes = max(nbytes, o.granted_bytes)
                o.reclaim_requested = False

    def request_reclaim(self, producer: str):
        """Producer wants its memory back (/reclaim_request)."""
        with self._lock:
            if producer in self._offers:
                self._offers[producer].reclaim_requested = True

    def reclaim_status(self, producer: str) -> bool:
        """True when every grant against this producer has been released."""
        with self._lock:
            return not any(g.producer == producer and not g.released
                           for g in self._grants)

    def withdraw(self, producer: str):
        with self._lock:
            self._offers.pop(producer, None)

    # -- consumer side ----------------------------------------------------
    def allocate(self, consumer: str, nbytes: float) -> List[Tuple[str, float]]:
        """Request offloaded memory (/allocate). Returns [(donor, bytes)...];
        empty list means fall back to host DRAM (paper §3)."""
        with self._lock:
            grants: List[Tuple[str, float]] = []
            remaining = nbytes
            producers = self._candidate_producers(consumer)
            for p in producers:
                o = self._offers.get(p)
                if o is None or o.available <= 0:
                    continue
                take = min(o.available, remaining)
                o.granted_bytes += take
                self._grants.append(Grant(consumer, p, take))
                grants.append((p, take))
                remaining -= take
                if remaining <= 0:
                    break
            return grants

    def free(self, consumer: str, producer: str, nbytes: float):
        """Consumer released offloaded pages (/free)."""
        with self._lock:
            for g in self._grants:
                if (g.consumer == consumer and g.producer == producer
                        and not g.released and g.nbytes >= nbytes - 1e-9):
                    g.released = True
                    o = self._offers.get(producer)
                    if o is not None:
                        o.granted_bytes -= g.nbytes
                    break

    def pending_reclaims(self, consumer: str) -> List[str]:
        """Donors that asked for their memory back (/respond poll)."""
        with self._lock:
            return sorted({g.producer for g in self._grants
                           if g.consumer == consumer and not g.released
                           and self._offers.get(g.producer) is not None
                           and self._offers[g.producer].reclaim_requested})

    # -- introspection ------------------------------------------------------
    def _candidate_producers(self, consumer: str) -> List[str]:
        if self.strict_pairing and consumer in self._pairing:
            return [self._pairing[consumer]]
        return sorted(self._offers, key=lambda p: -self._offers[p].available)

    def stats(self) -> Dict[str, Dict]:
        with self._lock:
            return {p: {"total": o.total_bytes, "granted": o.granted_bytes,
                        "reclaiming": o.reclaim_requested}
                    for p, o in self._offers.items()}
