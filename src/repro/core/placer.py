"""AQUA-PLACER — optimal model-to-server placement (paper §4, Algorithm 1).

Inputs: S servers × G GPUs, models m with memory requirement R_m
(R_m > 0: producer offering memory; R_m < 0: consumer with a deficit — the
paper's sign convention) and type t_m (+1 producer / -1 consumer).

   minimize   max_s(mem_s) + G_mem * max_s(eq_s)
   s.t.       sum_s x_{m,s} = 1            (each model on one server)
              sum_m x_{m,s} <= G           (G GPUs per server)
              mem_s = sum_m x_{m,s} R_m
              eq_s  = sum_m x_{m,s} t_m

Three solvers (cross-checked in tests):
  * ``milp``   — exact, scipy.optimize.milp (HiGHS branch-and-cut). The paper
                 uses Gurobi; HiGHS solves the paper's largest instance
                 (128 GPUs) in well under the paper's 45 s (Fig. 14).
  * ``bnb``    — exact branch-and-bound over *model-type counts* (models of
                 identical (R, t) are exchangeable, so the state space is the
                 multiset of per-type remaining counts). No solver dependency.
  * ``greedy`` — LPT-style heuristic + pairwise-swap local search for very
                 large clusters; used as the bound seed for ``bnb``.

After server assignment, producers and consumers inside a server are paired
one-to-one by stable matching on memory size (paper: "within each server it
matches producers to consumers using simple stable matching"); a producer's
fabric bandwidth is never shared between consumers.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class ModelSpec:
    name: str
    mem: float          # R_m: + producer / - consumer (GB)
    kind: str           # "producer" | "consumer"

    @property
    def t(self) -> int:
        return 1 if self.kind == "producer" else -1


@dataclass
class Placement:
    assignment: Dict[str, int]               # model -> server
    pairs: List[Tuple[str, str]]             # (consumer, producer) per server
    objective: float
    solve_time: float
    solver: str

    def servers(self) -> Dict[int, List[str]]:
        out: Dict[int, List[str]] = {}
        for m, s in self.assignment.items():
            out.setdefault(s, []).append(m)
        return out


def _objective(models: Sequence[ModelSpec], assign: Sequence[int], S: int,
               g_mem: float) -> float:
    mem = np.zeros(S)
    eq = np.zeros(S)
    for m, s in zip(models, assign):
        mem[s] += m.mem
        eq[s] += m.t
    return float(mem.max() + g_mem * eq.max())


# ---------------------------------------------------------------------------
# exact: scipy MILP (HiGHS)
# ---------------------------------------------------------------------------
def _solve_milp(models: Sequence[ModelSpec], S: int, G: int, g_mem: float):
    from scipy.optimize import LinearConstraint, Bounds, milp
    import scipy.sparse as sp

    M = len(models)
    # variables: x_{m,s} (M*S binaries), z1 (max mem), z2 (max eq)
    nx = M * S
    nv = nx + 2

    def xi(m, s):
        return m * S + s

    c = np.zeros(nv)
    c[nx] = 1.0
    c[nx + 1] = g_mem

    rows, cols, vals, lo, hi = [], [], [], [], []
    r = 0
    for m in range(M):                       # sum_s x = 1
        for s in range(S):
            rows.append(r); cols.append(xi(m, s)); vals.append(1.0)
        lo.append(1.0); hi.append(1.0); r += 1
    for s in range(S):                       # sum_m x <= G
        for m in range(M):
            rows.append(r); cols.append(xi(m, s)); vals.append(1.0)
        lo.append(0.0); hi.append(float(G)); r += 1
    for s in range(S):                       # mem_s - z1 <= 0
        for m in range(M):
            rows.append(r); cols.append(xi(m, s)); vals.append(models[m].mem)
        rows.append(r); cols.append(nx); vals.append(-1.0)
        lo.append(-np.inf); hi.append(0.0); r += 1
    for s in range(S):                       # eq_s - z2 <= 0
        for m in range(M):
            rows.append(r); cols.append(xi(m, s)); vals.append(float(models[m].t))
        rows.append(r); cols.append(nx + 1); vals.append(-1.0)
        lo.append(-np.inf); hi.append(0.0); r += 1

    A = sp.csr_matrix((vals, (rows, cols)), shape=(r, nv))
    integrality = np.concatenate([np.ones(nx), np.zeros(2)])
    bounds = Bounds(np.concatenate([np.zeros(nx), [-np.inf, -np.inf]]),
                    np.concatenate([np.ones(nx), [np.inf, np.inf]]))
    res = milp(c=c, constraints=LinearConstraint(A, lo, hi),
               integrality=integrality, bounds=bounds)
    if not res.success:
        raise RuntimeError(f"milp failed: {res.message}")
    x = res.x[:nx].reshape(M, S)
    assign = [int(np.argmax(x[m])) for m in range(M)]
    return assign


# ---------------------------------------------------------------------------
# exact: branch and bound over type counts (no solver dependency)
# ---------------------------------------------------------------------------
def _solve_bnb(models: Sequence[ModelSpec], S: int, G: int, g_mem: float,
               time_limit: float = 30.0):
    # group exchangeable models
    types: Dict[Tuple[float, int], List[int]] = {}
    for i, m in enumerate(models):
        types.setdefault((m.mem, m.t), []).append(i)
    tkeys = sorted(types, key=lambda k: -abs(k[0]))
    counts0 = tuple(len(types[k]) for k in tkeys)
    T = len(tkeys)

    best = {"obj": _objective(models, _solve_greedy(models, S, G, g_mem), S, g_mem)}
    best_combo: List[Optional[Tuple[Tuple[int, ...], ...]]] = [None]
    t0 = time.monotonic()
    seen = {}

    # enumerate per-server multisets (compositions of counts up to G models)
    def server_options(counts):
        opts = []
        def rec(i, left, cur, mem, eq):
            if i == T:
                opts.append((tuple(cur), mem, eq))
                return
            for n in range(0, min(counts[i], left) + 1):
                cur.append(n)
                rec(i + 1, left - n, cur, mem + n * tkeys[i][0], eq + n * tkeys[i][1])
                cur.pop()
        rec(0, G, [], 0.0, 0)
        return opts

    def rec(s, counts, max_mem, max_eq, chosen):
        if time.monotonic() - t0 > time_limit:
            return
        if s == S:
            if all(c == 0 for c in counts):
                obj = max_mem + g_mem * max_eq
                if obj < best["obj"] - 1e-9:
                    best["obj"] = obj
                    best_combo[0] = tuple(chosen)
            return
        key = (s, counts)
        lb = max_mem + g_mem * max_eq
        if key in seen and seen[key] <= lb + 1e-9:
            return
        seen[key] = lb
        if lb >= best["obj"] - 1e-9:
            return
        remaining_slots = (S - s) * G
        if sum(counts) > remaining_slots:
            return
        for combo, mem, eq in server_options(counts):
            if sum(combo) == 0 and sum(counts) > 0 and (S - s - 1) * G < sum(counts):
                continue
            nc = tuple(c - n for c, n in zip(counts, combo))
            rec(s + 1, nc, max(max_mem, mem), max(max_eq, eq), chosen + [combo])

    rec(0, counts0, -np.inf, -10**9, [])
    if best_combo[0] is None:
        return _solve_greedy(models, S, G, g_mem)
    assign = [0] * len(models)
    pools = {k: list(types[k]) for k in tkeys}
    for s, combo in enumerate(best_combo[0]):
        for ti, n in enumerate(combo):
            for _ in range(n):
                assign[pools[tkeys[ti]].pop()] = s
    return assign


# ---------------------------------------------------------------------------
# heuristic: greedy + local search
# ---------------------------------------------------------------------------
def _solve_greedy(models: Sequence[ModelSpec], S: int, G: int, g_mem: float):
    order = sorted(range(len(models)), key=lambda i: -abs(models[i].mem))
    mem = np.zeros(S)
    eq = np.zeros(S)
    load = np.zeros(S, int)
    assign = [0] * len(models)
    for i in order:
        m = models[i]
        best_s, best_cost = None, None
        for s in range(S):
            if load[s] >= G:
                continue
            nm, ne = mem.copy(), eq.copy()
            nm[s] += m.mem
            ne[s] += m.t
            cost = nm.max() + g_mem * ne.max()
            if best_cost is None or cost < best_cost:
                best_s, best_cost = s, cost
        if best_s is None:
            raise ValueError("more models than GPU slots")
        assign[i] = best_s
        mem[best_s] += m.mem
        eq[best_s] += m.t
        load[best_s] += 1
    # pairwise swap local search
    improved = True
    while improved:
        improved = False
        cur = _objective(models, assign, S, g_mem)
        for i, j in itertools.combinations(range(len(models)), 2):
            if assign[i] == assign[j]:
                continue
            assign[i], assign[j] = assign[j], assign[i]
            if _objective(models, assign, S, g_mem) < cur - 1e-12:
                improved = True
                break
            assign[i], assign[j] = assign[j], assign[i]
    return assign


# ---------------------------------------------------------------------------
# stable matching of producers to consumers inside each server
# ---------------------------------------------------------------------------
def _match_within_servers(models: Sequence[ModelSpec], assign: Sequence[int],
                          S: int) -> List[Tuple[str, str]]:
    pairs = []
    for s in range(S):
        here = [m for m, a in zip(models, assign) if a == s]
        cons = sorted([m for m in here if m.kind == "consumer"], key=lambda m: m.mem)
        prod = sorted([m for m in here if m.kind == "producer"], key=lambda m: -m.mem)
        # largest deficit gets the largest offer (assortative = stable here,
        # since both sides rank strictly by size)
        for c, p in zip(cons, prod):
            pairs.append((c.name, p.name))
    return pairs


def place(models: Sequence[ModelSpec], n_servers: int, gpus_per_server: int,
          gpu_mem: float = 80.0, solver: str = "auto",
          time_limit: float = 30.0) -> Placement:
    if len(models) > n_servers * gpus_per_server:
        raise ValueError("more models than GPUs in the cluster")
    t0 = time.monotonic()
    if solver == "auto":
        solver = "milp" if len(models) * n_servers <= 4096 else "greedy"
    if solver == "milp":
        try:
            assign = _solve_milp(models, n_servers, gpus_per_server, gpu_mem)
        except (ImportError, ValueError, RuntimeError):
            # scipy missing, MILP infeasible, or solver failure: fall back
            # to the exact branch-and-bound — never swallow KeyboardInterrupt
            # or genuine bugs under a blanket handler
            solver = "bnb"
            assign = _solve_bnb(models, n_servers, gpus_per_server, gpu_mem, time_limit)
    elif solver == "bnb":
        assign = _solve_bnb(models, n_servers, gpus_per_server, gpu_mem, time_limit)
    elif solver == "greedy":
        assign = _solve_greedy(models, n_servers, gpus_per_server, gpu_mem)
    else:
        raise ValueError(solver)
    dt = time.monotonic() - t0
    pairs = _match_within_servers(models, assign, n_servers)
    return Placement({m.name: s for m, s in zip(models, assign)}, pairs,
                     _objective(models, assign, n_servers, gpu_mem), dt, solver)
