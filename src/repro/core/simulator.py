"""Discrete-event serving simulator, priced by core/perfmodel.py.

Reproduces the paper's end-to-end serving figures on this CPU-only container:
the *logic* (schedulers, admission, paging decisions, coordinator protocol) is
the real AQUA implementation; only kernel wall-times are analytic. The same
scheduler code drives the real JAX engine in repro/serving (tiny models).

Schedulers:
  * ``vllm``      — continuous batching, FCFS admission gated on KV memory
                    (requests queue, possibly starving: paper Fig. 1a).
  * ``cfs``       — completely fair scheduling: token time-slices; at each
                    slice boundary the `max_running` prompts with the FEWEST
                    generated tokens run next (paper §5). Preempted prompts'
                    contexts page out; scheduled prompts' contexts page in.
                    ``offload_tier`` decides where: 'host' (PCIe — what vLLM+
                    CFS would do) or 'fabric' (AQUA TENSORS over NVLink/ICI).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.errors import CapacityError
from repro.core.perfmodel import (HardwareProfile, ModelCost,
                                  context_switch_time,
                                  overlapped_transfer_time, page_flip_time,
                                  retry_backoff_time)
from repro.serving.scheduler import split_step_budget


@dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    gen_len: int
    lora_bytes: float = 0.0
    # copy-on-write prefix sharing: requests with the same prefix_group
    # alias the physical pages of their common shared_prefix_len-token
    # prompt prefix — the prefix occupies KV capacity ONCE per group while
    # any member is resident, and a context switch moves it only when no
    # other member's pages keep it pinned (mirrors PagedStateRuntime).
    prefix_group: Optional[int] = None
    shared_prefix_len: int = 0
    # request lifecycle (mirrors the engine's ReqState): an e2e / first-token
    # deadline in seconds after arrival, enforced by the per-round sweep, and
    # the torn-down marker a "cancel" FaultEvent or deadline expiry stamps
    deadline_s: Optional[float] = None
    ttft_deadline_s: Optional[float] = None
    cancelled: bool = False
    cancel_reason: Optional[str] = None   # "fault" | "deadline"
    # progress
    generated: int = 0
    prefill_pos: int = 0             # prompt tokens prefilled so far (chunked)
    prefilled: bool = False
    ttft: Optional[float] = None
    finish: Optional[float] = None
    resident: bool = False           # context currently in local HBM
    recovered: bool = False          # lost its parked pages to a donor loss
    #                                  and recomputed from the prompt


@dataclass
class SimResult:
    requests: List[Request]
    timeline: List[Dict] = field(default_factory=list)

    def ttfts(self):
        return [r.ttft - r.arrival for r in self.requests if r.ttft is not None]

    def rcts(self):
        return [r.finish - r.arrival for r in self.requests if r.finish is not None]

    def p50(self, xs):
        xs = sorted(xs)
        return xs[len(xs) // 2] if xs else float("nan")


class ServingSimulator:
    def __init__(self, hw: HardwareProfile, model: ModelCost, *,
                 weight_bytes: float, kv_capacity_bytes: float,
                 scheduler: str = "vllm", offload_tier: str = "host",
                 slice_tokens: int = 5, max_running: int = 16,
                 coalesced: bool = True, paging: str = "paged",
                 step_tokens: Optional[int] = None,
                 overlap_pagein: bool = False,
                 fused_step: bool = True,
                 spec_chunk_ahead: bool = False,
                 coalesce_planes: bool = True,
                 prefix_cache: bool = True,
                 lora_cache_bytes: float = 0.0,
                 lora_num_adapters: int = 200,
                 faults=None,
                 admission: bool = False,
                 admission_headroom: float = 0.9,
                 prefill_admit_limit: Optional[int] = 4):
        self.hw = hw
        self.model = model
        self.weight_bytes = weight_bytes
        self.kv_cap = kv_capacity_bytes
        self.scheduler = scheduler
        self.tier = offload_tier
        self.slice_tokens = slice_tokens
        self.max_running = max_running
        self.coalesced = coalesced
        # step_tokens: chunked continuous-batching prefill — each scheduler
        # round spends at most this many tokens (+1 progress floor), split
        # between the round's decode iterations (lanes x slice for CFS) and
        # prompt chunks (None = whole-prompt prefill, the seed behavior).
        self.step_tokens = step_tokens
        # overlap_pagein: price CFS page-ins as prefetched transfers hidden
        # up to the round's compute time (perfmodel.overlapped_transfer_time)
        self.overlap_pagein = overlap_pagein
        # fused_step: the one-launch engine step — every decode iteration is
        # ONE jitted call carrying all requests' chunks, so dispatch
        # overhead per round is O(decode iterations); the per-request
        # baseline adds one call per granted chunk (O(admitted requests),
        # the Kossmann et al. between-launch idle regime). Priced by
        # ModelCost.launch_time.
        self.fused_step = fused_step
        # spec_chunk_ahead: leftover step-token slack speculatively prefills
        # the head-of-line waiting prompt (parked again right after) —
        # mirrors the engine's speculative chunk-ahead.
        self.spec_chunk_ahead = spec_chunk_ahead
        # coalesce_planes: a multi-plane (SSM/hybrid) context switch fuses
        # every plane into one message per (tier, donor); uncoalesced it
        # pays ModelCost.n_planes messages (the pre-fusion runtime).
        self.coalesce_planes = coalesce_planes
        # prefix_cache: the global radix prefix cache — a FINISHED group
        # member's written prefix stays adoptable (the runtime retains
        # refcount-0 pages until page pressure evicts them), so a later
        # arrival skips those prefill tokens and pays only the restore
        # bytes of the cached prefix on its first page-in. Off, adoption
        # requires a LIVE member (pure CoW sharing, the pre-cache model).
        self.prefix_cache = bool(prefix_cache)
        self.cache_hits = 0              # adoptions satisfied only by a
        self.cache_hit_tokens = 0        # finished member's cached pages
        self.adopted_tokens = 0          # prefill tokens skipped by ANY
        #                                  adoption (live-shared or cached)
        # 'paged': decode KV lives on pages; a context switch is a page-table
        # tier flip (no repack gather — matches the paged ServingEngine).
        # 'blob': the seed path — gather every leaf into a staging blob first.
        self.paging = paging
        self.lora_cache = lora_cache_bytes
        self.lora_num_adapters = lora_num_adapters
        # faults: optional core/faults.FaultInjector on the ANALYTIC clock —
        # transfer legs pay Bernoulli retry+backoff time, and time-scheduled
        # FaultEvents (at_time) fire at round boundaries: a donor_loss resets
        # its fraction of parked contexts to recompute from the prompt, a
        # lease_shrink degrades that fraction of future fabric flip bytes to
        # the host link (the reclaimed donor slots' pages now live on host)
        self.faults = faults
        self.leg_retries = 0
        self.donor_losses = 0
        self.lease_shrinks = 0
        # request-lifecycle counters (mirror EngineMetrics): teardowns
        # before completion and the deadline-expiry subset
        self.cancelled = 0
        self.deadline_missed = 0
        self._host_spill = 0.0
        # prefix sharing only exists for all-token-plane families: a
        # recurrent state page summarizes the whole prefix and cannot be
        # aliased (PagedStateRuntime forces sharing off when state_bytes>0),
        # so the simulator ignores prefix groups for those models
        self.prefix_sharing_ok = model.state_bytes == 0.0
        # overflow-swap churn (vllm branch): admission gates on CURRENT
        # bytes, but contexts grow one token per decode step — when the
        # admitted set's growth overshoots kv_cap, the latest-arrived
        # resident swaps out (vLLM swap preemption) and re-admits later,
        # paying the flip both ways. This is Ao et al.'s service-induced
        # congestion: churn rises exactly when load spikes. The stability
        # controller below exists to price TERMINAL bytes so it never fires.
        self.overflow_swaps = 0
        # admission: the SLO-aware stability-region controller of
        # serving/admission.py on the BYTE clock — one implementation, two
        # clocks (the engine instantiates it over per-plane page vectors).
        # Deferred requests stay queued (degrade-to-queue), invisible to
        # both scheduler branches until completions reopen the region.
        self.admission = None
        if admission:
            from repro.serving.admission import AdmissionController
            self.admission = AdmissionController(
                budget=lambda: np.array([self.kv_cap]),
                current_cost=lambda r, chosen: self._adm_cost(
                    r, chosen, terminal=False),
                terminal_cost=lambda r, chosen: self._adm_cost(
                    r, chosen, terminal=True),
                remaining_tokens=lambda r: (r.prompt_len - r.prefill_pos,
                                            r.gen_len - r.generated),
                headroom=admission_headroom,
                step_tokens=self.step_tokens,
                prefill_admit_limit=prefill_admit_limit)

    def _adm_cost(self, r: Request, chosen, *, terminal: bool) -> np.ndarray:
        """Marginal context bytes of ``r`` against the committed set — at
        the current context, or grown to completion (``terminal``), the
        quantity naive current-bytes admission ignores."""
        ctx = r.prompt_len + (r.gen_len if terminal else r.generated)
        groups = {c.prefix_group for c in chosen
                  if c.prefix_group is not None}
        if (self.prefix_sharing_ok and r.prefix_group is not None
                and r.prefix_group in groups):
            return np.array([self.model.unique_context_bytes(
                ctx, min(r.shared_prefix_len, r.prompt_len))])
        return np.array([self.model.context_bytes(ctx)])

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *, horizon: float = 1e9) -> SimResult:
        t = 0.0
        pending: List[Request] = sorted(requests, key=lambda r: r.arrival)
        waiting: List[Request] = []
        running: List[Request] = []
        done: List[Request] = []
        timeline = []

        def kv_bytes(r: Request) -> float:
            # whole dynamic context: token-paged KV/latents + the fixed
            # recurrent state planes (nonzero for SSM/hybrid families)
            return self.model.context_bytes(r.prompt_len + r.generated)

        def marginal_bytes(r: Request, groups) -> float:
            # dedup-aware admission: a member of a prefix group whose shared
            # pages are already counted (another member in `groups`) costs
            # only its exclusive context
            if (self.prefix_sharing_ok and r.prefix_group is not None
                    and r.prefix_group in groups):
                return self.model.unique_context_bytes(
                    r.prompt_len + r.generated,
                    min(r.shared_prefix_len, r.prompt_len))
            return kv_bytes(r)

        def used_bytes() -> float:
            groups, total = set(), 0.0
            for r in running:
                if r.resident:
                    total += marginal_bytes(r, groups)
                    if r.prefix_group is not None:
                        groups.add(r.prefix_group)
            return total

        def resident_groups() -> set:
            return {r.prefix_group for r in running
                    if r.resident and r.prefix_group is not None}

        if self.kv_cap <= 0:
            raise CapacityError(
                "model does not fit this serving unit (use "
                "HardwareProfile.pod_slice for TP-sharded serving)")
        stall = 0
        while (pending or waiting or running) and t < horizon:
            # fire time-scheduled fault events due on the analytic clock
            if self.faults is not None:
                for ev in self.faults.due_events(now=t):
                    if ev.kind == "donor_loss":
                        self.donor_losses += 1
                        victims = [r for r in waiting
                                   if not r.resident and r.finish is None
                                   and (r.prefill_pos > 0 or r.generated > 0)]
                        n = math.ceil(ev.frac * len(victims))
                        for r in victims[:n]:
                            # parked pages died with the donor: recompute
                            # from the prompt (TTFT stands — the first token
                            # was already served; only remaining work re-runs)
                            r.generated = 0
                            r.prefill_pos = 0
                            r.prefilled = False
                            r.recovered = True
                            if self.admission is not None:
                                # reset work must re-price against the
                                # (possibly contracted) stability region
                                self.admission.forget(r.rid)
                    elif ev.kind == "lease_shrink":
                        self.lease_shrinks += 1
                        self._host_spill = min(
                            1.0, self._host_spill + ev.frac)
                    elif ev.kind == "cancel":
                        # client abandonment (make_cancel_events): tear the
                        # named request out of whichever pool holds it —
                        # same schedule, both clocks
                        for pool in (running, waiting, pending):
                            v = next((x for x in pool if x.rid == ev.rid),
                                     None)
                            if v is None:
                                continue
                            pool.remove(v)
                            v.cancelled, v.cancel_reason = True, "fault"
                            v.resident = False
                            self.cancelled += 1
                            if self.admission is not None:
                                self.admission.forget(v.rid)
                            break
            # admit arrivals. Prefix sharing adopts at arrival (mirroring
            # the engine's submit-time index lookup): an arriving member of
            # a prefix group whose shared prefix some member already wrote
            # skips those chunks (>= 1 position remains for the first-token
            # logits, as in the engine).
            while pending and pending[0].arrival <= t:
                r = pending.pop(0)
                skip = min(r.shared_prefix_len, r.prompt_len - 1)
                # adoptable from a member that STILL HOLDS pages covering
                # the skipped prefix (live CoW sharing), or — with the
                # prefix cache on — from a FINISHED member whose refcount-0
                # pages the runtime retained (a cache hit: the prefill
                # tokens are skipped, only restore bytes are paid below)
                if (self.prefix_sharing_ok and r.prefix_group is not None
                        and skip > 0):
                    live = any(o is not r
                               and o.prefix_group == r.prefix_group
                               and o.finish is None
                               and o.prefill_pos >= skip
                               for o in requests)
                    cached = (self.prefix_cache
                              and any(o is not r
                                      and o.prefix_group == r.prefix_group
                                      and o.finish is not None
                                      and o.prefill_pos >= skip
                                      for o in requests))
                    if live or cached:
                        r.prefill_pos = skip
                        self.adopted_tokens += skip
                        if not live:
                            self.cache_hits += 1
                            self.cache_hit_tokens += skip
                waiting.append(r)
            # deadline sweep (mirrors the engine's _shed_expired): expired
            # waiters are shed before admission can see them, expired
            # runners drop their residency the same round. TTFT deadlines
            # bind only until the first token landed.
            for r in list(waiting) + list(running):
                age = t - r.arrival
                ttft_miss = (r.ttft_deadline_s is not None and r.ttft is None
                             and age > r.ttft_deadline_s)
                e2e_miss = r.deadline_s is not None and age > r.deadline_s
                if not (ttft_miss or e2e_miss):
                    continue
                (waiting if r in waiting else running).remove(r)
                r.cancelled, r.cancel_reason = True, "deadline"
                r.resident = False
                self.cancelled += 1
                self.deadline_missed += 1
                if self.admission is not None:
                    self.admission.forget(r.rid)
            if not running and not waiting:
                t = pending[0].arrival
                continue
            # reject requests whose context alone exceeds capacity
            if not running and waiting and not pending:
                stall += 1
                if stall > 3:
                    for r in list(waiting):
                        if kv_bytes(r) > self.kv_cap:
                            waiting.remove(r)
                    if not waiting:
                        break
            else:
                stall = 0

            # stability-region admission gate: deferred requests are
            # invisible to BOTH scheduler branches this round (degrade to
            # queue); they retry every round and admit as completions free
            # bytes. Without it every waiter is eligible.
            deferred: List[Request] = []
            sched_wait = waiting
            if self.admission is not None:
                sched_wait, deferred = self.admission.filter(waiting, running)

            step_time = 0.0
            pagein_time = 0.0
            if self.scheduler == "vllm":
                # overflow preemption churn: admission gated on CURRENT
                # bytes, but last round's decode growth may have pushed the
                # resident set past kv_cap. vLLM's default preemption
                # RECOMPUTES: the latest-arrived resident discards its KV
                # (free — no transfer) and must re-prefill its whole prompt
                # when re-admitted (at least one request keeps running).
                # Under a spike this thrashes: the newest residents are
                # evicted before their first token, re-admit, re-prefill a
                # few chunks, get evicted again — Ao et al.'s
                # service-induced congestion, the TTFT divergence the
                # stability controller exists to prevent (it prices
                # TERMINAL bytes, so the overshoot never happens).
                while (used_bytes() > self.kv_cap
                       and sum(1 for r in running if r.resident) > 1):
                    victim = max((r for r in running if r.resident),
                                 key=lambda r: (r.arrival, r.rid))
                    victim.resident = False
                    # the rebuild covers the WHOLE context: prompt plus the
                    # KV of every token generated so far (the generated
                    # text is kept; its cache is not) — encoded as negative
                    # prefill_pos so prompt_len - prefill_pos is the
                    # remaining rebuild work
                    victim.prefill_pos = -victim.generated
                    victim.prefilled = False
                    running.remove(victim)
                    waiting.append(victim)
                    if sched_wait is not waiting:
                        # still admitted — stays eligible for re-admission
                        sched_wait.append(victim)
                    self.overflow_swaps += 1
                # FCFS admission while KV fits (physical bytes: a shared
                # prefix already resident via its group is not re-counted)
                for r in list(sched_wait):
                    if used_bytes() + marginal_bytes(r, resident_groups()) \
                            <= self.kv_cap \
                            and len(running) < self.max_running:
                        waiting.remove(r)
                        # a non-resident written context pages in on
                        # admission: a recovered request's prefix, or an
                        # adopted (cached) prefix — pinned to zero bytes
                        # when a live group member already holds it LOCAL
                        if not r.resident and (r.prefilled
                                               or r.prefill_pos > 0):
                            pinned = (r.prefix_group is not None
                                      and r.prefix_group
                                      in resident_groups())
                            pagein_time += self._switch_time(
                                r, direction="in", shared_pinned=pinned)
                        r.resident = True
                        running.append(r)
                ntok = 1
            else:  # cfs
                # slice boundary: fair-pick the least-served prompts under
                # the PHYSICAL byte budget (marginal cost per prefix group);
                # deferred requests never enter the fair pick
                candidates = running + sched_wait
                candidates.sort(key=lambda r: (r.generated, r.arrival))
                nxt = []
                acc = 0.0
                groups: set = set()
                for r in candidates:
                    b = marginal_bytes(r, groups)
                    if acc + b > self.kv_cap or len(nxt) >= self.max_running:
                        continue
                    acc += b
                    nxt.append(r)
                    if r.prefix_group is not None:
                        groups.add(r.prefix_group)
                # page out the preempted, page in the scheduled. A shared
                # prefix moves ONCE per group: it stays pinned while any
                # member remains scheduled, and when a whole group parks,
                # only the first member's switch carries the prefix bytes.
                nxt_groups = {r.prefix_group for r in nxt
                              if r.prefix_group is not None}
                moved_groups: set = set()
                for r in running:
                    if r not in nxt and r.resident:
                        pinned = (r.prefix_group is not None
                                  and (r.prefix_group in nxt_groups
                                       or r.prefix_group in moved_groups))
                        step_time += self._switch_time(r, direction="out",
                                                       shared_pinned=pinned)
                        if r.prefix_group is not None:
                            moved_groups.add(r.prefix_group)
                        r.resident = False
                in_groups = {r.prefix_group for r in nxt
                             if r.resident and r.prefix_group is not None}
                for r in nxt:
                    # anything with resident KV pays the page-in: a request
                    # parked MID-prefill moves its prefill_pos-token prefix
                    # (minus a shared prefix some member already restored)
                    if not r.resident and (r.prefilled or r.prefill_pos > 0):
                        pinned = (r.prefix_group is not None
                                  and r.prefix_group in in_groups)
                        pagein_time += self._switch_time(r, direction="in",
                                                         shared_pinned=pinned)
                    r.resident = True
                    if r.prefix_group is not None:
                        in_groups.add(r.prefix_group)
                waiting = [r for r in candidates if r not in nxt] + deferred
                running = nxt
                ntok = self.slice_tokens
            if not self.overlap_pagein:
                # seed accounting: page-ins serialize before compute
                step_time += pagein_time
                pagein_time = 0.0

            if not running:
                # nothing fits / nothing to do; advance to next arrival
                t = pending[0].arrival if pending else t + 1e-3
                continue

            # prefill under the ROUND token budget: decode lanes reserve one
            # token per decode iteration of this round (a CFS round decodes
            # `slice_tokens` per lane), the rest is handed out as prompt
            # chunks (None = whole prompts, the seed behavior)
            compute_time = 0.0
            n_chunk_calls = 0
            piggyback_tokens = 0        # chunk FLOPs riding fused decode
            lanes = [r for r in running
                     if r.prefilled and r.generated < r.gen_len]
            pend = [r for r in running if not r.prefilled]
            # roofline-aware chunk cap (mirrors the engine): piggybacked
            # chunk FLOPs spread across the round's ntok fused decode
            # iterations, each hiding up to its memory-bound FLOPs slack,
            # so the round's chunk budget is capped at the per-iteration
            # window times the planned iterations — tokens beyond it would
            # extend the round linearly instead of riding the stream free
            flops_slack = None
            if self.fused_step and lanes and self.step_tokens is not None:
                ctx0 = (sum(r.prompt_len + r.generated for r in lanes)
                        / len(lanes))
                flops_slack = ntok * self.model.piggyback_tokens(
                    self.hw, len(lanes), ctx0, self.weight_bytes)
            chunks = split_step_budget(self.step_tokens, len(lanes) * ntok,
                                       [r.prompt_len - r.prefill_pos
                                        for r in pend],
                                       flops_slack=flops_slack)
            for r, c in zip(pend, chunks):
                if c <= 0:
                    continue
                n_chunk_calls += 1
                if self.fused_step and lanes:
                    # fused one-launch step: the chunk shares the decode
                    # iteration's weight pass — its FLOPs fold into that
                    # iteration's roofline max below
                    piggyback_tokens += c
                    dt = 0.0
                else:
                    dt = self.model.prefill_time(self.hw, c)
                r.prefill_pos += c
                if r.prefill_pos >= r.prompt_len:
                    r.prefilled = True
                    dt += self._lora_load_time(r)
                compute_time += dt
                step_time += dt

            # speculative chunk-ahead: leftover budget slack prefills
            # WAITING prompts (all but each one's last position) — arrival
            # order, extending PAST the head-of-line waiter while slack
            # lasts — whose pages flip back out right after; mirrors the
            # engine. The win is largest under FCFS admission, where
            # waiters can sit slot-blocked behind long decodes for many
            # slack-rich rounds. The slack is capped by the same FLOPs
            # piggyback window as the granted chunks.
            if self.spec_chunk_ahead and self.step_tokens is not None:
                slack = (self.step_tokens - len(lanes) * ntok - sum(chunks))
                if flops_slack is not None:
                    slack = min(slack, max(flops_slack - sum(chunks), 0))
                n_groups = (1 if self.coalesce_planes
                            else self.model.n_planes)
                for spec in sorted(waiting,
                                   key=lambda r: (r.arrival, r.rid)):
                    if slack <= 0:
                        break
                    if spec.prefilled:
                        continue
                    if (self.admission is not None
                            and spec.rid not in self.admission.admitted_rids):
                        # never speculate on unadmitted work: its pages
                        # would land inside the region the controller is
                        # holding open
                        continue
                    c = min(slack, spec.prompt_len - spec.prefill_pos - 1)
                    if c <= 0:
                        continue
                    if spec.prefill_pos > 0:        # page its prefix back in
                        step_time += page_flip_time(
                            self.hw,
                            self.model.context_bytes(spec.prefill_pos),
                            tier=self.tier, n_groups=n_groups)
                    if self.fused_step and lanes:
                        # the speculative chunk rides the fused decode
                        # launch too — its FLOPs hide under the
                        # memory-bound stream below
                        piggyback_tokens += c
                    else:
                        dt = self.model.prefill_time(self.hw, c)
                        compute_time += dt
                        step_time += dt
                    spec.prefill_pos += c
                    n_chunk_calls += 1
                    slack -= c
                    step_time += page_flip_time(   # park it again
                        self.hw,
                        self.model.context_bytes(spec.prefill_pos),
                        tier=self.tier, n_groups=n_groups)

            # decode ntok tokens for the running batch; each fused
            # iteration carries piggybacked chunk FLOPs up to its own
            # memory-bound window in its roofline max (one launch, one
            # weight pass per iteration) — leftovers beyond every window
            # pay linear prefill time after the loop
            n_decode_iters = 0
            for _ in range(ntok):
                live = [r for r in running
                        if r.prefilled and r.generated < r.gen_len]
                if not live:
                    break
                n_decode_iters += 1
                ctx = sum(r.prompt_len + r.generated for r in live) / len(live)
                take = min(piggyback_tokens,
                           self.model.piggyback_tokens(
                               self.hw, len(live), ctx, self.weight_bytes))
                dt = self.model.fused_step_time(
                    self.hw, len(live), ctx, self.weight_bytes, take)
                piggyback_tokens -= take
                compute_time += dt
                step_time += dt
                for r in live:
                    r.generated += 1
                    if r.ttft is None:
                        r.ttft = t + step_time
            if piggyback_tokens > 0:
                # chunk FLOPs no decode window absorbed (decode drained
                # early, or grants exceeded the round's windows)
                dt = self.model.prefill_time(self.hw, piggyback_tokens)
                piggyback_tokens = 0
                compute_time += dt
                step_time += dt
            # launch-count model: fused = one jitted call per engine step
            # (chunks ride the decode iterations); per-request baseline adds
            # one call per granted chunk — O(admitted requests) per round
            if self.fused_step:
                n_calls = max(n_decode_iters,
                              1 if (n_chunk_calls or n_decode_iters) else 0)
            else:
                n_calls = n_chunk_calls + n_decode_iters
            step_time += self.model.launch_time(self.hw, n_calls)
            if pagein_time:
                # prefetched page-ins: transfer hidden up to the compute time
                step_time += overlapped_transfer_time(compute_time,
                                                      pagein_time)
            t += step_time

            # retire finished
            for r in list(running):
                if r.generated >= r.gen_len:
                    r.finish = t
                    r.resident = False
                    running.remove(r)
                    done.append(r)
                    if self.admission is not None:
                        self.admission.forget(r.rid)
            timeline.append({"t": t, "running": len(running),
                             "waiting": len(waiting),
                             "deferred": len(deferred),
                             "kv_used": used_bytes(),
                             "occ_frac": (used_bytes() / self.kv_cap
                                          if self.kv_cap > 0 else 0.0)})
        return SimResult(requests, timeline)

    # ------------------------------------------------------------------
    def _switch_time(self, r: Request, direction: str,
                     shared_pinned: bool = False) -> float:
        # resident context only: a mid-prefill request moves just the chunked
        # prefix it has written so far (prefill_pos == prompt_len once done)
        # plus its fixed state pages (SSM/hybrid recurrent leaves).
        # shared_pinned: the request's shared prefix pages stay put (another
        # group member keeps them resident, or they already moved this
        # round) — only the exclusive context flips tiers.
        ctx = (r.prefill_pos if not r.prefilled else r.prompt_len) + r.generated
        shared = (min(r.shared_prefix_len, ctx)
                  if shared_pinned and self.prefix_sharing_ok else 0.0)
        kv = self.model.unique_context_bytes(ctx, shared)
        if self.paging == "paged" and self.coalesced:
            # page-native runtime: tier flip of the page payload. With
            # cross-plane coalescing every plane of the request rides ONE
            # message per (tier, donor); uncoalesced, a hybrid/SSM flip
            # pays one message per plane (ModelCost.n_planes)
            n_groups = 1 if self.coalesce_planes else self.model.n_planes
            spill = self._host_spill if self.tier == "fabric" else 0.0
            base = page_flip_time(self.hw, kv * (1.0 - spill),
                                  tier=self.tier, n_groups=n_groups)
            if spill > 0.0:
                # lease-shrunk donor fleet: the reclaimed slots' share of
                # the flip degrades to the PCIe host link
                base += page_flip_time(self.hw, kv * spill, tier="host",
                                       n_groups=n_groups)
        else:
            # uncoalesced: one message per layer-page fragment (Fig. 3a pain)
            n_frag = (1 if self.coalesced
                      else max(1, int(kv // (2 * 16 * 128 * 64))))
            base = context_switch_time(self.hw, kv, tier=self.tier,
                                       coalesced=self.coalesced,
                                       n_fragments=n_frag)
        return base + self._retry_time(base)

    def _retry_time(self, leg_time: float) -> float:
        """Transient transfer-leg faults under the injector: each failed
        attempt re-pays the leg plus exponential backoff, bounded by the
        injector's retry cap (its consecutive-failure cap guarantees the
        bound is reachable)."""
        if self.faults is None:
            return 0.0
        extra, attempt = 0.0, 0
        while (attempt < self.faults.max_leg_retries
               and self.faults.leg_fails(self.tier, None)):
            attempt += 1
            self.leg_retries += 1
            extra += leg_time + retry_backoff_time(self.hw, attempt)
        return extra

    def _lora_load_time(self, r: Request) -> float:
        """Paper setup: N adapters, random per request, LRU cache holding
        `lora_cache_bytes` of them -> hit probability = resident fraction."""
        if r.lora_bytes <= 0:
            return 0.0
        resident = self.lora_cache / max(r.lora_bytes, 1.0)
        hit_p = min(resident / max(self.lora_num_adapters, 1), 1.0)
        h = (r.rid * 2654435761) % (1 << 32) / float(1 << 32)  # deterministic
        if h < hit_p:
            return 0.0
        link = self.hw.fabric if self.tier == "fabric" else self.hw.host_link
        # vLLM's default path issues one transfer per layer-tensor; the AQUA
        # integration copies the adapter "as is" in one message (paper §B.1)
        msgs = 1 if (self.coalesced and self.tier == "fabric") else 8 * 48
        return link.time(r.lora_bytes, n_messages=msgs)


# ---------------------------------------------------------------------------
# Long-prompt streaming decode (paper Fig. 7 / FlexGen comparison)
# ---------------------------------------------------------------------------
def long_prompt_tokens_per_s(hw: HardwareProfile, model: ModelCost, *,
                             ctx_tokens: int, free_hbm_bytes: float,
                             weight_bytes: float, tier: str) -> float:
    """Decode throughput when the context exceeds free HBM.

    FlexGen's cache policy is all-or-nothing for a given layer: when the
    context does not fit, the whole KV cache is pinned off-device and streams
    through the link every decode step (paper §6.1). AQUA keeps the same
    policy but the cache lives in a donor GPU's HBM, so the stream runs at
    fabric (NVLink/ICI) bandwidth — that bandwidth ratio is the paper's 6x
    (Fig. 7).
    """
    kv_total = model.kv_bytes(ctx_tokens)
    offloaded = kv_total > free_hbm_bytes
    link = hw.fabric if tier == "fabric" else hw.host_link
    t_stream = link.time(kv_total) if offloaded else 0.0
    # attention reads stream from the link; weights still read from HBM
    t_comp = model.decode_step_time(hw, 1, 0 if offloaded else ctx_tokens,
                                    weight_bytes)
    return 1.0 / (t_stream + t_comp)
