"""Roofline analysis from compiled HLO (§Roofline deliverable).

Three terms per (arch × shape × mesh) cell, all in seconds:

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

``cost_analysis()`` counts every computation in the module ONCE — it does
NOT multiply while-loop bodies by their trip counts (verified empirically;
scan-over-layers would be undercounted by L). So this module parses the
post-SPMD optimized HLO text instead and walks the computation call graph:

    cost(entry) = Σ op costs + fusion -> cost(called)
                + while -> trip_count x (cost(body) + cost(cond))

Trip counts come from the ``backend_config={"known_trip_count":{"n":"N"}}``
annotation XLA attaches to canonical scan-derived loops (fallback: parse the
`compare(..., constant)` in the condition computation).

Costs counted per instruction (per-device, post-partitioning):
  flops       : dot (2*M*N*K*batch), convolution (approximated via shapes)
  bytes       : sum of unique operand + output buffer sizes of non-fusion
                top-level ops (a standard HBM-traffic proxy post-fusion)
  collectives : output bytes of all-reduce / all-gather / reduce-scatter /
                all-to-all / collective-permute (x2 for all-reduce: ring
                all-reduce moves ~2x the payload)
"""
from __future__ import annotations

import gzip
import json
import math
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
               "token": 0, "opaque": 0, "s4": 1, "u4": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# v5e constants (from the brief)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+) = (.*)$")
# first lowercase identifier followed by '(' after the type — the opcode.
# (type strings contain only dtype[dims]{layout} and /*index=N*/ comments,
# none of which match word-followed-by-paren)
_OP_RE = re.compile(r"(?:^|[\s,*/])([a-z][\w\-]*)\(")


def _parse_shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            # computation header: [ENTRY] %name (params) -> type {
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", line.strip())
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if cur is None:
            continue
        m = _ASSIGN_RE.match(line)
        if m:
            name, rest = m.groups()
            op_m = _OP_RE.search(rest)
            if not op_m:
                continue
            op = op_m.group(1)
            type_str = rest[: op_m.start()].strip()
            ins = Instr(name, type_str, op, line)
            cur.instrs.append(ins)
            cur.by_name[name] = ins
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _arg_names(argstr: str) -> List[str]:
    """Operand names from an HLO operand list. Newer XLA prints typed
    operands (``dot(f32[64,256]{1,0} %Arg_0.1, ...)``) whose dims contain
    commas, so prefer the %-prefixed tokens; fall back to comma splitting
    for the older bare-name dialect."""
    names = re.findall(r"%([\w.\-]+)", argstr)
    if names:
        return names
    return [a.strip().split()[-1] for a in argstr.split(",") if a.strip()]


def _dot_flops(instr: Instr, comp: Computation) -> float:
    """2 * batch * M * N * K from output shape + contracting dims."""
    out_dt, out_dims = _shape_dims(instr.type_str)
    m = re.search(r"dot\(([^)]*)\)", instr.line)
    if not m:
        return 0.0
    args = _arg_names(m.group(1))
    lhs = comp.by_name.get(args[0]) if args else None
    k = 1
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    if lhs is not None and cd:
        _, ldims = _shape_dims(lhs.type_str)
        for d in cd.group(1).split(","):
            if d and int(d) < len(ldims):
                k *= ldims[int(d)]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * k


def _conv_flops(instr: Instr, comp: Computation) -> float:
    out_dt, out_dims = _shape_dims(instr.type_str)
    m = re.search(r"convolution\(([^)]*)\)", instr.line)
    if not m:
        return 0.0
    args = _arg_names(m.group(1))
    rhs = comp.by_name.get(args[1]) if len(args) > 1 else None
    kn = 1
    if rhs is not None:
        _, rdims = _shape_dims(rhs.type_str)
        for d in rdims:
            kn *= d
    out_n = 1
    for d in out_dims:
        out_n *= d
    # output elems x (kernel elems / out_channels) x 2 — good enough for the
    # stub conv frontends; transformers have no convs on the hot path
    return 2.0 * out_n * max(kn, 1) ** 0.5


def _trip_count(instr: Instr, comps: Dict[str, Computation]) -> int:
    m = re.search(r'known_trip_count.*?n["\':\s]+(\d+)', instr.line)
    if m:
        return int(m.group(1))
    m = re.search(r"condition=%?([\w.\-]+)", instr.line)
    if m and m.group(1) in comps:
        for ins in comps[m.group(1)].instrs:
            c = re.search(r"compare\([^)]*\).*direction=LT", ins.line)
            if c:
                k = re.search(r"constant\((\d+)\)", ins.line)
                if k:
                    return int(k.group(1))
        # condition compares against a constant defined in the computation
        consts = [re.search(r"constant\((\d+)\)", i.line)
                  for i in comps[m.group(1)].instrs]
        consts = [int(c.group(1)) for c in consts if c]
        if consts:
            return max(consts)
    return 1


def _update_bytes(instr: Instr, comp: Computation) -> int:
    """Traffic of an in-place dynamic-update-slice/scatter = 2x update size."""
    m = re.search(rf"{instr.op}\(([^)]*)\)", instr.line)
    if m:
        args = _arg_names(m.group(1))
        if len(args) >= 2 and args[1] in comp.by_name:
            return 2 * _parse_shape_bytes(comp.by_name[args[1]].type_str)
    return _parse_shape_bytes(instr.type_str) // 8


def _dims_only(type_str: str) -> str:
    m = _SHAPE_RE.search(type_str)
    return m.group(2) if m else ""


def _fusion_out_bytes(ins: Instr, called: Computation) -> int:
    """Fusion output traffic. A fusion whose root is an in-place update
    (dynamic-update-slice / scatter, possibly convert-wrapped by the CPU
    backend's float normalization — bf16 has no native CPU support, so XLA
    wraps bf16 loop updates in f32 round-trips that do NOT exist on the TPU
    target) only writes the updated slice, not the whole stacked buffer."""
    if not called.instrs:
        return _parse_shape_bytes(ins.type_str)
    root = called.instrs[-1]
    if root.op in ("dynamic-update-slice", "scatter"):
        return _update_bytes(root, called)
    if root.op == "tuple":
        m = re.search(r"tuple\(([^)]*)\)", root.line)
        tot = 0
        if m:
            for a in _arg_names(m.group(1)):
                el = called.by_name.get(a)
                if el is None:
                    continue
                if el.op in ("dynamic-update-slice", "scatter"):
                    tot += _update_bytes(el, called)
                else:
                    tot += _parse_shape_bytes(el.type_str)
        return tot or _parse_shape_bytes(ins.type_str)
    # convert-rooted fusion hiding a full-size in-place update
    out_dims = _dims_only(ins.type_str)
    for el in called.instrs:
        if el.op in ("dynamic-update-slice", "scatter") \
                and _dims_only(el.type_str) == out_dims:
            return _update_bytes(el, called)
    return _parse_shape_bytes(ins.type_str)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, int] = field(default_factory=dict)

    def __add__(self, o):
        cc = dict(self.coll_counts)
        for k, v in o.coll_counts.items():
            cc[k] = cc.get(k, 0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll_bytes + o.coll_bytes, cc)

    def scale(self, k: float):
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    {n: int(v * k) for n, v in self.coll_counts.items()})


def cost_of(comp: Computation, comps: Dict[str, Computation],
            memo: Dict[str, Cost]) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Cost()          # guard cycles
    total = Cost()
    for ins in comp.instrs:
        if ins.op == "dot":
            total.flops += _dot_flops(ins, comp)
            total.bytes += _parse_shape_bytes(ins.type_str)
        elif ins.op == "convolution":
            total.flops += _conv_flops(ins, comp)
            total.bytes += _parse_shape_bytes(ins.type_str)
        elif ins.op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", ins.line)
            if m and m.group(1) in comps:
                called = comps[m.group(1)]
                total = total + cost_of(called, comps, memo)
                total.bytes += _fusion_out_bytes(ins, called)
            else:
                total.bytes += _parse_shape_bytes(ins.type_str)
        elif ins.op == "while":
            trips = _trip_count(ins, comps)
            sub = Cost()
            for key in ("body", "condition"):
                m = re.search(rf"{key}=%?([\w.\-]+)", ins.line)
                if m and m.group(1) in comps:
                    sub = sub + cost_of(comps[m.group(1)], comps, memo)
            total = total + sub.scale(trips)
        elif ins.op in ("call", "conditional", "async-start"):
            for m in re.finditer(r"(?:to_apply|calls|branch_computations)="
                                 r"\{?%?([\w.\-]+)\}?", ins.line):
                if m.group(1) in comps:
                    total = total + cost_of(comps[m.group(1)], comps, memo)
        elif any(ins.op.startswith(c) for c in COLLECTIVES):
            b = _parse_shape_bytes(ins.type_str)
            if ins.op.startswith("all-reduce"):
                b *= 2                 # ring AR moves ~2x payload
            total.coll_bytes += b
            total.coll_counts[ins.op] = total.coll_counts.get(ins.op, 0) + 1
            total.bytes += _parse_shape_bytes(ins.type_str)
        elif ins.op in ("dynamic-update-slice", "scatter"):
            # in-place update: traffic = read+write of the UPDATE operand,
            # not the whole (potentially multi-GB stacked) buffer
            total.bytes += _update_bytes(ins, comp)
        elif ins.op in ("copy", "transpose", "reshape", "broadcast", "reduce",
                        "gather", "dynamic-slice",
                        "sort", "iota",
                        "add", "multiply", "select", "exponential", "tanh",
                        "concatenate", "slice", "pad", "compare", "divide"):
            # NB: bare `convert` is excluded — on the CPU backend XLA's float
            # normalization inserts bf16<->f32 round-trips that fuse away on
            # the TPU target
            total.bytes += _parse_shape_bytes(ins.type_str)
    memo[comp.name] = total
    return total


def analyze_text(text: str) -> Cost:
    comps = parse_hlo(text)
    entry = "__entry__" if "__entry__" in comps else list(comps)[-1]
    return cost_of(comps[entry], comps, {})


def analyze_file(path: str) -> Cost:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return analyze_text(f.read())


@dataclass
class Roofline:
    flops: float
    bytes: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float

    def table_row(self) -> dict:
        return {
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bottleneck": self.bottleneck,
            "model_flops": self.model_flops, "useful_ratio": self.useful_ratio,
        }


def roofline_from_cost(cost: Cost, *, model_flops_per_device: float,
                       n_links: float = 2.0) -> Roofline:
    """All quantities are PER DEVICE (post-SPMD HLO is per-device)."""
    t_c = cost.flops / PEAK_FLOPS
    t_m = cost.bytes / HBM_BW
    t_x = cost.coll_bytes / (ICI_BW * n_links)
    term = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
               key=lambda kv: kv[1])
    return Roofline(cost.flops, cost.bytes, cost.coll_bytes, t_c, t_m, t_x,
                    term[0], model_flops_per_device,
                    model_flops_per_device / cost.flops if cost.flops else 0.0)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D (train) or 2*N*D (inference), N = active params."""
    from repro.core.perfmodel import ModelCost
    n_active = ModelCost.from_config(cfg).n_params
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch          # decode: one token/seq
