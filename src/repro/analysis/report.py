"""Builds the EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
results directory (JSON records + gzipped optimized HLO per cell).
"""
from __future__ import annotations

import glob
import json
import os
import re
from typing import Dict, List, Optional

from repro.analysis.roofline import (Cost, analyze_file, model_flops,
                                     roofline_from_cost)
from repro.configs import SHAPES_BY_NAME, get_config

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "internvl2-1b", "rwkv6-3b", "gemma-7b", "qwen1.5-0.5b", "minicpm-2b",
    "gemma3-12b", "deepseek-v2-lite-16b", "dbrx-132b", "whisper-tiny",
    "jamba-v0.1-52b",
]
_CELL_RE = re.compile(r"(.+)_(train_4k|prefill_32k|decode_32k|long_500k)$")


def _chips(mesh_name: str) -> int:
    return 512 if mesh_name == "pod512" else 256


def collect(results_dir: str, mesh_name: str) -> List[Dict]:
    out = []
    d = os.path.join(results_dir, mesh_name)
    for jf in sorted(glob.glob(os.path.join(d, "*.json"))):
        rec = json.load(open(jf))
        stem = os.path.basename(jf)[:-5]
        m = _CELL_RE.match(stem)
        if not m:
            continue
        arch, shape_name = m.groups()
        rec["arch"], rec["shape"] = arch, shape_name
        hlo = os.path.join(d, stem + ".hlo.gz")
        if rec.get("status") == "ok" and os.path.exists(hlo):
            cost = analyze_file(hlo)
            mf = model_flops(get_config(arch), SHAPES_BY_NAME[shape_name]) \
                / _chips(mesh_name)
            rl = roofline_from_cost(cost, model_flops_per_device=mf)
            rec["roofline"] = {
                "flops": cost.flops, "bytes": cost.bytes,
                "coll_bytes": cost.coll_bytes,
                "coll_ops": cost.coll_counts,
                **rl.table_row(),
            }
        out.append(rec)
    return out


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table_md(records: List[Dict], mesh_name: str) -> str:
    lines = [
        f"### Roofline — {mesh_name} "
        f"({_chips(mesh_name)} chips, v5e: 197 TF/s bf16, 819 GB/s HBM, 2x50 GB/s ICI)",
        "",
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | "
        "MODEL_FLOPS/HLO_FLOPs | mem/dev (args+temp) | notes |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    index = {(r["arch"], r["shape"]): r for r in records}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = index.get((arch, shape))
            if r is None:
                continue
            if r.get("status") == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                             f"SKIP: {r.get('reason','')[:60]} |")
                continue
            if r.get("status") != "ok":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                             f"ERROR: {r.get('error','')[:60]} |")
                continue
            rl = r["roofline"]
            mem = r["memory"]
            memgb = (mem["argument_size_in_bytes"]
                     + mem["temp_size_in_bytes"]) / 1e9
            note = "" if memgb <= 16 else f"OVER 16GB ({memgb:.0f}GB)"
            lines.append(
                f"| {arch} | {shape} | {_fmt_s(rl['t_compute_s'])} | "
                f"{_fmt_s(rl['t_memory_s'])} | {_fmt_s(rl['t_collective_s'])} | "
                f"**{rl['bottleneck']}** | {rl['useful_ratio']:.2f} | "
                f"{memgb:.1f} GB | {note} |")
    return "\n".join(lines)


def dryrun_table_md(records: List[Dict], mesh_name: str) -> str:
    lines = [
        f"### Dry-run — {mesh_name}",
        "",
        "| arch | shape | status | compile | HLO GFLOPs/dev | bytes/dev | "
        "collective bytes/dev | collective ops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    index = {(r["arch"], r["shape"]): r for r in records}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = index.get((arch, shape))
            if r is None:
                continue
            if r.get("status") != "ok":
                lines.append(f"| {arch} | {shape} | {r.get('status')} | — | — "
                             f"| — | — | — |")
                continue
            rl = r.get("roofline", {})
            ops = rl.get("coll_ops", {})
            opss = ", ".join(f"{k}:{v}" for k, v in sorted(ops.items()))
            lines.append(
                f"| {arch} | {shape} | ok | {r['compile_s']}s | "
                f"{rl.get('flops', 0)/1e9:.1f} | {rl.get('bytes', 0)/1e9:.1f} GB | "
                f"{rl.get('coll_bytes', 0)/1e9:.2f} GB | {opss[:90]} |")
    return "\n".join(lines)


def summarize(results_dir: str) -> str:
    parts = []
    for mesh_name in ("pod256", "pod512"):
        if not os.path.isdir(os.path.join(results_dir, mesh_name)):
            continue
        recs = collect(results_dir, mesh_name)
        parts.append(dryrun_table_md(recs, mesh_name))
        parts.append("")
        parts.append(roofline_table_md(recs, mesh_name))
        parts.append("")
    return "\n".join(parts)


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    print(summarize(d))
