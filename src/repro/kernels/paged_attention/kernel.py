"""Paged attention Pallas TPU kernels (serving hot spot).

Five entry points:
  * ``paged_attention``       — split K/V pools ``(K, P, page, hd)``
  * ``paged_attention_pool``  — fused page-major pool ``(P, 2, K, page, hd)``:
    the AquaTensor LOCAL pool IS the operand (batched block tables; the
    serving runtime's layout — tier migration moves whole slots, no repack)
  * ``paged_prefill_attention_pool`` — query-BLOCK variant of the fused-pool
    kernel: a chunk of ``Tc`` query tokens per sequence attends causally to
    every page written so far (chunked continuous-batching prefill). The
    page-iteration axis and online-softmax accumulators are identical to the
    decode variant, so a token's softmax reduction order is the same for any
    chunk split — chunked prefill is bit-identical across chunk sizes.
  * ``paged_mixed_attention_pool`` — MIXED-MODE variant: one launch serves a
    packed batch of decode lanes AND prefill chunk rows against the same
    pool. Each row carries ``(q_start, n_real, is_decode)`` metadata: a
    decode lane is a one-token row (``n_real = 1``) whose single query sits
    at absolute position ``q_start``; a chunk row is ``n_real`` real tokens
    at ``q_start + t``. The page loop and accumulators are the decode/chunk
    kernels', so a fused engine step is bit-identical to the per-request
    calls it replaces — while issuing ONE launch per layer instead of one
    per admitted request.
  * ``append_kv``             — page-append writer: one decode token's K/V
    into each sequence's current page, in place via input-output aliasing

The block table is passed as a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``) so the K/V BlockSpec index maps can resolve
``block_tables[b, i]`` **before** the DMA is issued — each grid step streams
exactly one page per kv head from the HBM pool into VMEM, which is precisely
the access pattern the paged pool is laid out for. Online softmax accumulators
live in VMEM scratch and persist across the page-iteration (minor-most) grid
axis. Pages past ``lengths[b]`` are masked (their DMA still targets page id 0,
a resident dummy, so no out-of-bounds access happens).

VMEM working set per step: q (G, hd) + k,v (page, hd) + acc (G, hd) f32
≈ 0.3 MB at page=64, hd=256 — far below the ~16 MB VMEM budget, leaving room
for the double-buffered page DMAs Mosaic inserts automatically.

COMPILED pass: every attention entry point declares its grid semantics to
the Mosaic compiler — the batch/packed-row axis and the kv-head axis are
``parallel`` (rows are independent; the compiler may partition them across
the two TPU megacores), while the page-iteration axis is ``arbitrary`` (the
online-softmax accumulators in VMEM scratch carry across it, a sequential
reduction). Megacore partitioning splits whole rows, never a row's page
loop, so each row's reduction order — and therefore its output — is
bit-identical to the interpret path and the per-request references. The
one-launch engine step (``paged_mixed_attention_pool``) thus runs as a real
partitioned kernel on TPU; on the CPU backend the same programs execute in
interpret mode (``ops._on_cpu``), where the declared semantics are carried
but unused.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# grid = (rows, kv heads, pages-per-sequence): rows/heads partition across
# megacores, the page axis is the online-softmax reduction
_POOL_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _compiler_params(dimension_semantics):
    """Mosaic compiler params, tolerant of the class name moving between
    jax releases (``TPUCompilerParams`` -> ``CompilerParams``); None when
    neither exists so ``pallas_call`` falls back to default semantics."""
    cls = (getattr(pltpu, "CompilerParams", None)
           or getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        return None
    try:
        return cls(dimension_semantics=tuple(dimension_semantics))
    except TypeError:
        return None


def _paged_kernel(block_tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (page, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G,page)
    pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == npages - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def _paged_pool_kernel(block_tables_ref, lengths_ref, q_ref, kv_ref, o_ref,
                       acc_ref, m_ref, l_ref, *, page: int, scale: float):
    """Fused-pool variant: one (1, 2, 1, page, hd) block carries the K and V
    halves of a page, so each grid step issues a single DMA per page."""
    b = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
    k = kv_ref[0, 0, 0].astype(jnp.float32)                # (page, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = kv_ref[0, 1, 0].astype(jnp.float32)                # (page, hd)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == npages - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def paged_attention_pool(q, kv_pool, block_tables, lengths, *,
                         scale: float | None = None, interpret: bool = False):
    """Batched block-table decode attention over a fused page-major pool.

    This is the serving-runtime layout: ``kv_pool`` IS the AquaTensor LOCAL
    pool, page-major so tier migration moves whole slots without repacking.

    q:            (B, H, hd)                   one query token per sequence
    kv_pool:      (P, 2, K, page, hd)          [:,0]=K, [:,1]=V
    block_tables: (B, pps) int32               physical page slots per sequence
    lengths:      (B,) int32                   tokens present per sequence
    -> (B, H, hd)
    """
    B, H, hd = q.shape
    P, _, K, page, _ = kv_pool.shape
    G = H // K
    pps = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(B, K, G, hd)
    kernel = functools.partial(_paged_pool_kernel, page=page, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_tables, lengths
        grid=(B, K, pps),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, i, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 2, 1, page, hd),
                         lambda b, h, i, bt, ln: (bt[b, i], 0, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=_compiler_params(_POOL_SEMANTICS),
        interpret=interpret,
    )(block_tables, lengths, qg, kv_pool)
    return out.reshape(B, H, hd)


def _chunk_pool_kernel(block_tables_ref, starts_ref, q_ref, kv_ref, o_ref,
                       acc_ref, m_ref, l_ref, *, page: int, gsize: int,
                       scale: float):
    """Query-block fused-pool variant: rows are (token, q-head-in-group)
    pairs, so row r is chunk token r // gsize. The causal mask compares each
    page position against the row's absolute position ``q_start + t``; the
    page loop and accumulators are otherwise the decode kernel's."""
    b = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (Tc*G, hd)
    k = kv_ref[0, 0, 0].astype(jnp.float32)                # (page, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    q_pos = starts_ref[b] + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // gsize
    s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = kv_ref[0, 1, 0].astype(jnp.float32)                # (page, hd)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == npages - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def paged_prefill_attention_pool(q, kv_pool, block_tables, q_starts, *,
                                 scale: float | None = None,
                                 interpret: bool = False):
    """Chunked-prefill attention over the fused page-major pool.

    Each sequence contributes a CHUNK of ``Tc`` query tokens at absolute
    positions ``q_starts[b] + t`` that attend causally to every page the
    sequence has written so far (including the chunk's own K/V, which the
    caller writes into the pool first).

    q:            (B, Tc, H, hd)       one chunk of query tokens per sequence
    kv_pool:      (P, 2, K, page, hd)  [:,0]=K, [:,1]=V
    block_tables: (B, pps) int32       physical page slots per sequence
                                       (padding points at a resident dummy)
    q_starts:     (B,) int32           absolute position of each chunk's
                                       first token
    -> (B, Tc, H, hd)
    """
    B, Tc, H, hd = q.shape
    P, _, K, page, _ = kv_pool.shape
    G = H // K
    pps = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # rows = (token, head-in-group): row r is token r // G of the chunk
    qg = (q.reshape(B, Tc, K, G, hd).transpose(0, 2, 1, 3, 4)
          .reshape(B, K, Tc * G, hd))
    kernel = functools.partial(_chunk_pool_kernel, page=page, gsize=G,
                               scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_tables, q_starts
        grid=(B, K, pps),
        in_specs=[
            pl.BlockSpec((1, 1, Tc * G, hd), lambda b, h, i, bt, st: (b, h, 0, 0)),
            pl.BlockSpec((1, 2, 1, page, hd),
                         lambda b, h, i, bt, st: (bt[b, i], 0, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Tc * G, hd),
                               lambda b, h, i, bt, st: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Tc * G, hd), jnp.float32),
            pltpu.VMEM((Tc * G, 1), jnp.float32),
            pltpu.VMEM((Tc * G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Tc * G, hd), q.dtype),
        compiler_params=_compiler_params(_POOL_SEMANTICS),
        interpret=interpret,
    )(block_tables, q_starts, qg, kv_pool)
    return (out.reshape(B, K, Tc, G, hd).transpose(0, 2, 1, 3, 4)
            .reshape(B, Tc, H, hd))


def _mixed_pool_kernel(block_tables_ref, starts_ref, n_reals_ref, decode_ref,
                       q_ref, kv_ref, o_ref, acc_ref, m_ref, l_ref, *,
                       page: int, gsize: int, scale: float):
    """Mixed-mode fused-pool kernel: every row of the packed batch is a
    query block of Tc tokens with per-row ``(q_start, n_real, is_decode)``
    metadata. A decode lane's single real token (row t = 0) attends to
    ``k_pos <= q_start`` — exactly the decode kernel's ``pos < length``
    mask with ``length = q_start + 1`` — and its tail rows (t >= n_real,
    which is 1) are fully masked, degenerating to a finite uniform mean the
    caller never reads. A chunk row's token t attends to
    ``k_pos <= q_start + t`` at EVERY row, bucket-pad rows included:
    garbage rows must stay bit-identical to the per-request chunk kernel's
    because their K/V was written into the page window (positions later
    chunks overwrite) and the next layer's writes are computed from their
    outputs. The page loop and the online-softmax accumulators are shared
    with the decode and chunk kernels — a row's reduction order never
    depends on what else rides the launch."""
    b = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (Tc*G, hd)
    k = kv_ref[0, 0, 0].astype(jnp.float32)                # (page, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    t = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // gsize
    dec = decode_ref[b] != 0
    q_pos = starts_ref[b] + jnp.where(dec, 0, t)
    valid = (k_pos <= q_pos) & (~dec | (t < n_reals_ref[b]))
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = kv_ref[0, 1, 0].astype(jnp.float32)                # (page, hd)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == npages - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def paged_mixed_attention_pool(q, kv_pool, block_tables, q_starts, n_reals,
                               is_decode, *, scale: float | None = None,
                               interpret: bool = False):
    """Fused mixed-mode attention: decode lanes + prefill chunk rows in ONE
    launch against the page-major pool.

    q:            (R, Tc, H, hd)       packed rows — decode lanes carry their
                                       single query token at t = 0
    kv_pool:      (P, 2, K, page, hd)  [:,0]=K, [:,1]=V
    block_tables: (R, pps) int32       physical page slots per row
                                       (padding points at a resident dummy)
    q_starts:     (R,) int32           absolute position of the row's first
                                       token (decode: the token's position)
    n_reals:      (R,) int32           real tokens in the row (decode: 1;
                                       bucket-pad rows: 0 — fully masked)
    is_decode:    (R,) int32           1 marks a decode lane
    -> (R, Tc, H, hd)
    """
    R, Tc, H, hd = q.shape
    P, _, K, page, _ = kv_pool.shape
    G = H // K
    pps = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = (q.reshape(R, Tc, K, G, hd).transpose(0, 2, 1, 3, 4)
          .reshape(R, K, Tc * G, hd))
    kernel = functools.partial(_mixed_pool_kernel, page=page, gsize=G,
                               scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,          # block_tables, q_starts, n_reals, dec
        grid=(R, K, pps),
        in_specs=[
            pl.BlockSpec((1, 1, Tc * G, hd),
                         lambda b, h, i, bt, st, nr, dc: (b, h, 0, 0)),
            pl.BlockSpec((1, 2, 1, page, hd),
                         lambda b, h, i, bt, st, nr, dc: (bt[b, i], 0, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Tc * G, hd),
                               lambda b, h, i, bt, st, nr, dc: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Tc * G, hd), jnp.float32),
            pltpu.VMEM((Tc * G, 1), jnp.float32),
            pltpu.VMEM((Tc * G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, K, Tc * G, hd), q.dtype),
        compiler_params=_compiler_params(_POOL_SEMANTICS),
        interpret=interpret,
    )(block_tables, q_starts, n_reals, is_decode, qg, kv_pool)
    return (out.reshape(R, K, Tc, G, hd).transpose(0, 2, 1, 3, 4)
            .reshape(R, Tc, H, hd))


def _append_kernel(slots_ref, offs_ref, k_ref, v_ref, pool_ref, out_ref, *,
                   page: int):
    """Copy the target page block, then overwrite one token row of K and V."""
    b = pl.program_id(0)
    off = offs_ref[b]
    out_ref[...] = pool_ref[...]
    out_ref[0, 0, :, pl.ds(off, 1), :] = k_ref[0][:, None, :]
    out_ref[0, 1, :, pl.ds(off, 1), :] = v_ref[0][:, None, :]


def append_kv(kv_pool, k_new, v_new, slots, offsets, *, interpret: bool = False):
    """Page-append writer: one decode token's K/V into its page, per sequence.

    kv_pool: (P, 2, K, page, hd); k_new/v_new: (B, K, hd);
    slots: (B,) int32 physical page slot holding the token's position;
    offsets: (B,) int32 row within the page (= pos % page).
    Returns the updated pool (in place on TPU via input-output aliasing).
    """
    P, _, K, page, hd = kv_pool.shape
    B = k_new.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # slots, offsets
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, K, hd), lambda b, s, o: (b, 0, 0)),       # k_new
            pl.BlockSpec((1, K, hd), lambda b, s, o: (b, 0, 0)),       # v_new
            pl.BlockSpec((1, 2, K, page, hd),
                         lambda b, s, o: (s[b], 0, 0, 0, 0)),          # pool
        ],
        out_specs=pl.BlockSpec((1, 2, K, page, hd),
                               lambda b, s, o: (s[b], 0, 0, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_append_kernel, page=page),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(kv_pool.shape, kv_pool.dtype),
        input_output_aliases={4: 0},           # pool (incl. scalar args) -> out
        interpret=interpret,
    )(slots, offsets, k_new, v_new, kv_pool)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: float | None = None, interpret: bool = False):
    """q: (B,H,hd); k/v_pages: (K,P,page,hd); block_tables: (B,pps); lengths (B,)."""
    B, H, hd = q.shape
    K, P, page, _ = k_pages.shape
    G = H // K
    pps = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(B, K, G, hd)
    kernel = functools.partial(_paged_kernel, page=page, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_tables, lengths
        grid=(B, K, pps),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, i, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, hd), lambda b, h, i, bt, ln: (h, bt[b, i], 0, 0)),
            pl.BlockSpec((1, 1, page, hd), lambda b, h, i, bt, ln: (h, bt[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=_compiler_params(_POOL_SEMANTICS),
        interpret=interpret,
    )(block_tables, lengths, qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
