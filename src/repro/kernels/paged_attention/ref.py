"""Pure-jnp oracles for paged attention.

These are the correctness anchors for BOTH kernel passes: the interpret-mode
path the CPU CI runs AND the compiled TPU pass (megacore-partitioned grid,
``kernel._POOL_SEMANTICS``) must match these references bit-for-bit — the
kernels' page-loop reduction order deliberately mirrors the f32 online
softmax written here, and megacore partitioning only ever splits whole
rows, so no legal lowering may reassociate a row's reduction.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                        scale: float | None = None):
    """Decode attention over a paged KV pool.

    q:            (B, H, hd)            one query token per sequence
    k_pages/v_pages: (K, P, page, hd)   global page pool per kv head
    block_tables: (B, pages_per_seq) int32  page ids per sequence
    lengths:      (B,) int32            tokens present per sequence
    -> (B, H, hd)
    """
    B, H, hd = q.shape
    K, P, page, _ = k_pages.shape
    G = H // K
    pps = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # gather per-sequence K/V: (B, K, pps*page, hd)
    kg = k_pages[:, block_tables]            # (K, B, pps, page, hd)
    vg = v_pages[:, block_tables]
    kg = jnp.moveaxis(kg, 1, 0).reshape(B, K, pps * page, hd)
    vg = jnp.moveaxis(vg, 1, 0).reshape(B, K, pps * page, hd)

    qg = q.reshape(B, K, G, hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, kg).astype(jnp.float32) * scale
    pos = jnp.arange(pps * page)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, vg)
    return out.reshape(B, H, hd)


def paged_attention_pool_ref(q, kv_pool, block_tables, lengths,
                             scale: float | None = None):
    """Oracle for the fused page-major pool layout.

    q: (B,H,hd); kv_pool: (P,2,K,page,hd); block_tables: (B,pps); lengths (B,).
    """
    k_pages = jnp.moveaxis(kv_pool[:, 0], 1, 0)       # (K, P, page, hd)
    v_pages = jnp.moveaxis(kv_pool[:, 1], 1, 0)
    return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               scale=scale)


def paged_prefill_attention_pool_ref(q, kv_pool, block_tables, q_starts,
                                     scale: float | None = None):
    """Oracle for the query-block (chunked prefill) fused-pool variant.

    q: (B,Tc,H,hd); kv_pool: (P,2,K,page,hd); block_tables: (B,pps);
    q_starts: (B,) absolute position of each chunk's first token.
    """
    B, Tc, H, hd = q.shape
    _, _, K, page, _ = kv_pool.shape
    G = H // K
    pps = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    k_pages = jnp.moveaxis(kv_pool[:, 0], 1, 0)       # (K, P, page, hd)
    v_pages = jnp.moveaxis(kv_pool[:, 1], 1, 0)
    kg = jnp.moveaxis(k_pages[:, block_tables], 1, 0).reshape(B, K, pps * page, hd)
    vg = jnp.moveaxis(v_pages[:, block_tables], 1, 0).reshape(B, K, pps * page, hd)

    qg = q.reshape(B, Tc, K, G, hd)
    scores = jnp.einsum("btkgd,bksd->bkgts", qg, kg).astype(jnp.float32) * scale
    k_pos = jnp.arange(pps * page)[None, None, None, None, :]
    q_pos = (q_starts[:, None] + jnp.arange(Tc)[None, :])[:, None, None, :, None]
    scores = jnp.where(k_pos <= q_pos, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bksd->bkgtd", probs, vg)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tc, H, hd)


def paged_mixed_attention_pool_ref(q, kv_pool, block_tables, q_starts,
                                   n_reals, is_decode,
                                   scale: float | None = None):
    """Oracle for the mixed-mode (decode lanes + prefill chunk rows) variant.

    q: (R,Tc,H,hd); kv_pool: (P,2,K,page,hd); block_tables: (R,pps);
    q_starts/n_reals/is_decode: (R,) per-row metadata — a decode lane is a
    one-token row (n_real 1) at absolute position q_start whose tail rows
    are fully masked (finite uniform-mean garbage, never read); a chunk
    row attends causally at every row INCLUDING bucket padding, matching
    the per-request chunk kernel bit-exactly (garbage rows' K/V sits in
    the page window until later chunks overwrite it).
    """
    R, Tc, H, hd = q.shape
    _, _, K, page, _ = kv_pool.shape
    G = H // K
    pps = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    k_pages = jnp.moveaxis(kv_pool[:, 0], 1, 0)       # (K, P, page, hd)
    v_pages = jnp.moveaxis(kv_pool[:, 1], 1, 0)
    kg = jnp.moveaxis(k_pages[:, block_tables], 1, 0).reshape(R, K, pps * page, hd)
    vg = jnp.moveaxis(v_pages[:, block_tables], 1, 0).reshape(R, K, pps * page, hd)

    qg = q.reshape(R, Tc, K, G, hd)
    scores = jnp.einsum("btkgd,bksd->bkgts", qg, kg).astype(jnp.float32) * scale
    k_pos = jnp.arange(pps * page)[None, None, None, None, :]
    t = jnp.arange(Tc)[None, :]
    dec = is_decode[:, None] != 0
    q_pos = (q_starts[:, None]
             + jnp.where(dec, 0, t))[:, None, None, :, None]
    valid = (k_pos <= q_pos) \
        & (~dec | (t < n_reals[:, None]))[:, None, None, :, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bksd->bkgtd", probs, vg)
    return out.transpose(0, 3, 1, 2, 4).reshape(R, Tc, H, hd)


def append_kv_ref(kv_pool, k_new, v_new, slots, offsets):
    """Oracle for the page-append writer.

    kv_pool: (P,2,K,page,hd); k_new/v_new: (B,K,hd); slots/offsets: (B,).
    """
    B, K, hd = k_new.shape
    heads = jnp.arange(K)[None, :]                    # broadcast to (B, K)
    kv_pool = kv_pool.at[slots[:, None], 0, heads,
                         offsets[:, None]].set(k_new.astype(kv_pool.dtype))
    kv_pool = kv_pool.at[slots[:, None], 1, heads,
                         offsets[:, None]].set(v_new.astype(kv_pool.dtype))
    return kv_pool
