"""Jit'd public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@jax.jit
def paged_attention(q, k_pages, v_pages, block_tables, lengths):
    return _kernel(q, k_pages, v_pages, block_tables, lengths,
                   interpret=_on_cpu())
