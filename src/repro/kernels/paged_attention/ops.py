"""Jit'd public wrappers for paged decode attention + page writers.

These are the ops the serving hot path calls. Backend policy — enforced by
a CI grep-guard (no hard-coded interpreter pin anywhere under ``src/``):

  * On TPU the kernels run COMPILED, with megacore/grid partitioning
    declared over the packed row and kv-head axes
    (``kernel._POOL_SEMANTICS``) — partitioning splits whole rows, never a
    row's page loop, so compiled outputs are bit-identical to interpret
    mode and the per-request references.
  * On the CPU backend the same programs run in interpret mode. The ONLY
    sanctioned way to request it on an engine-path call is this module's
    ``interpret=_on_cpu()`` — hard-coding the flag to ``True`` would
    silently pin the compiled pass back to the interpreter on hardware.

``impl='xla'`` callers can use the jnp oracles in ``ref.py`` instead.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import append_kv as _append_kv
from repro.kernels.paged_attention.kernel import paged_attention as _kernel
from repro.kernels.paged_attention.kernel import \
    paged_attention_pool as _kernel_pool
from repro.kernels.paged_attention.kernel import \
    paged_mixed_attention_pool as _kernel_mixed
from repro.kernels.paged_attention.kernel import \
    paged_prefill_attention_pool as _kernel_chunk


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@jax.jit
def paged_attention(q, k_pages, v_pages, block_tables, lengths):
    return _kernel(q, k_pages, v_pages, block_tables, lengths,
                   interpret=_on_cpu())


@jax.jit
def paged_attention_pool(q, kv_pool, block_tables, lengths):
    """Decode attention reading the fused page-major AquaTensor pool."""
    return _kernel_pool(q, kv_pool, block_tables, lengths,
                        interpret=_on_cpu())


@jax.jit
def paged_prefill_attention_pool(q, kv_pool, block_tables, q_starts):
    """Chunked-prefill attention: a query BLOCK per sequence attends causally
    to every page written so far (the query-block fused-pool variant)."""
    return _kernel_chunk(q, kv_pool, block_tables, q_starts,
                         interpret=_on_cpu())


@jax.jit
def paged_mixed_attention_pool(q, kv_pool, block_tables, q_starts, n_reals,
                               is_decode):
    """Mixed-mode fused-pool attention: a packed batch of decode lanes and
    prefill chunk rows — per-row (q_start, n_real, is_decode) metadata —
    served in ONE launch per layer (the fused engine step's hot kernel)."""
    return _kernel_mixed(q, kv_pool, block_tables, q_starts, n_reals,
                         is_decode, interpret=_on_cpu())


@jax.jit
def append_kv(kv_pool, k_new, v_new, slots, offsets):
    """Append one decode token's K/V into each sequence's current page."""
    return _append_kv(kv_pool, k_new, v_new, slots, offsets,
                      interpret=_on_cpu())
