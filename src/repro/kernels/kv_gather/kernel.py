"""AQUA coalescing gather/scatter Pallas kernel.

The paper's custom CUDA gather/scatter kernels (§5 "Small transfers are slow
over NVlinks") exist because the fabric only reaches peak bandwidth for large
messages: scattered KV pages of the prompts being context-switched must be
packed into ONE contiguous staging buffer before the inter-accelerator copy,
and scattered back on the way in.

TPU adaptation: the kernel is a pure DMA engine — the scalar-prefetched page
id list drives the input (gather) or output (scatter) BlockSpec index map, so
Mosaic turns each grid step into an HBM->HBM DMA of one page, double-buffered
across steps. The kernel body is a copy; no compute units are used, matching
the paper's observation (Fig. 11) that providers see <5% interference.

The staging buffer is then moved between devices by a single large
``jax.lax.ppermute`` (see repro/distributed/collectives.py), which is the ICI
analogue of the paper's single large cudaMemcpyPeer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(ids_ref, src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def gather_pages(pool, page_ids, *, interpret: bool = False):
    """pool: (P, page, d); page_ids: (n,) int32 -> staging (n, page, d)."""
    P, page, d = pool.shape
    n = page_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, page, d), lambda i, ids: (ids[i], 0, 0))],
        out_specs=pl.BlockSpec((1, page, d), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, page, d), pool.dtype),
        interpret=interpret,
    )(page_ids, pool)


def scatter_pages(pool, staging, page_ids, *, interpret: bool = False):
    """Write staging (n, page, d) into pool (P, page, d) at page_ids; returns pool.

    Uses input-output aliasing so the pool is updated in place on TPU (no
    second copy of a multi-GB page pool).
    """
    P, page, d = pool.shape
    n = page_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),                         # pool (aliased)
            pl.BlockSpec((1, page, d), lambda i, ids: (i, 0, 0)),      # staging
        ],
        out_specs=pl.BlockSpec((1, page, d), lambda i, ids: (ids[i], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},       # pool (arg idx incl. scalar) -> out 0
        interpret=interpret,
    )(page_ids, pool, staging)


def _scatter_kernel(ids_ref, pool_ref, staging_ref, out_ref):
    out_ref[...] = staging_ref[...]
