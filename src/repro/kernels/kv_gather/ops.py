"""Jit'd public wrappers for the AQUA coalescing gather/scatter."""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.kv_gather.kernel import gather_pages as _gather
from repro.kernels.kv_gather.kernel import scatter_pages as _scatter


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _canon(pool):
    """Kernel operates on (P, page, d); fold arbitrary page payloads to 2-D."""
    P = pool.shape[0]
    if pool.ndim == 3:
        return pool, pool.shape[1:]
    payload = pool.shape[1:]
    n = int(np.prod(payload)) if payload else 1
    d = 128 if n % 128 == 0 else 1
    return pool.reshape(P, n // d, d), payload


@jax.jit
def gather_pages(pool, page_ids):
    """Coalesce scattered pages into one contiguous staging buffer."""
    p3, payload = _canon(pool)
    out = _gather(p3, page_ids, interpret=_on_cpu())
    return out.reshape((page_ids.shape[0],) + tuple(payload))


@jax.jit
def scatter_pages(pool, staging, page_ids):
    """Scatter a staging buffer back into the page pool (in-place on TPU)."""
    p3, payload = _canon(pool)
    s3 = staging.reshape((staging.shape[0],) + p3.shape[1:])
    out = _scatter(p3, s3, page_ids, interpret=_on_cpu())
    return out.reshape(pool.shape)
