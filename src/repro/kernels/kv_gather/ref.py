"""Pure-jnp oracle for the AQUA coalescing gather/scatter."""
from __future__ import annotations

import jax.numpy as jnp


def gather_pages_ref(pool, page_ids):
    """pool: (P, page, d); page_ids: (n,) -> staging (n, page, d)."""
    return pool[page_ids]


def scatter_pages_ref(pool, staging, page_ids):
    """Inverse: write staging (n, page, d) back into pool at page_ids."""
    return pool.at[page_ids].set(staging)
