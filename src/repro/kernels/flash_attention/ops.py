"""Jit'd public wrapper for the flash attention kernel.

On CPU (this container) the kernel executes in interpret mode for validation;
on TPU it compiles via Mosaic. The dry-run model path uses the XLA einsum
implementation so ``cost_analysis`` reflects true FLOPs (DESIGN.md §8).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 512):
    return _kernel(q, k, v, causal=causal, window=window,
                   block_q=block_q, block_k=block_k, interpret=_on_cpu())
