"""Pure-jnp oracle for blocked causal/windowed GQA flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B, Sq, H, hd); k,v: (B, Sk, K, hd); H % K == 0 -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32) * scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)      # right-aligned queries
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(B, Sq, H, hd)
