"""Blocked flash attention Pallas TPU kernel (train / prefill hot spot).

Grid: (B*H, num_q_blocks, num_k_blocks); the k axis is minor-most so the f32
VMEM scratch accumulators (acc, running max m, running sum l) persist across
k iterations of one (bh, q_block) cell. GQA is handled in the k/v index maps
(q head -> kv head via integer division), so KV tiles are fetched once per
query-head group member without materializing repeated heads in HBM.

Block sizes default to (128, 512): MXU-aligned (multiples of 128 on the
contracting/lane dims) and small enough that the working set
  q(128,hd) + k(512,hd) + v(512,hd) + acc(128,hd) f32
fits VMEM comfortably for hd <= 256 (<= ~1.3 MB at hd=256).
Causal/windowed cells are skipped *in-index-space* (the kernel computes the
mask from absolute positions), so sliding-window layers do O(S*W) work.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, seq_q: int,
                  seq_k: int, causal: bool, window: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (seq_k - seq_q)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    mask &= k_pos < seq_k

    q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 512, interpret: bool = False):
    """q: (B, Sq, H, hd); k,v: (B, Sk, K, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)

    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=Sq, seq_k=Sk, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
