"""Chunked RWKV-6 WKV recurrence Pallas TPU kernel.

The recurrence
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T),   S_t = diag(e^{w_t}) S_{t-1} + k_t v_t^T
is sequential per token; a naive scan leaves the MXU idle. The kernel uses a
chunked form with chunk length C:

  lw      = cumsum(w) within chunk                       (inclusive log-decay)
  y_cross = (r ⊙ e^{lw_prev}) @ S_in                     # (C,hd)x(hd,hd) MXU matmul
  y_intra = A @ v + (Σ_i r⊙u⊙k)·v                        # (C,C)x(C,hd) MXU matmul
    with A[t,τ] = Σ_i r_t[i] k_τ[i] e^{lw_prev[t,i]-lw[τ,i]}  (τ < t)
  S_out   = e^{lw_last} ⊙ S_in + (k ⊙ e^{lw_last-lw})^T @ v  # (hd,C)x(C,hd) matmul

Numerical-stability invariant: every exponent that is ever materialized is
≤ 0 — the A matrix uses the *pairwise* decay difference directly (a (C,C,hd)
VPU broadcast-multiply-reduce) instead of the e^{+lw}/e^{-lw} factorization,
which overflows for strong decay channels and silently destroys
adjacent-token contributions when clamped. Validated against the sequential
oracle across decay magnitudes in tests/test_kernels.py.

Grid: (B*H, T/C), state (hd,hd) f32 persists in VMEM scratch across the
sequential chunk axis. VMEM at C=32, hd=64: pairwise tensor 32*32*64*4B
(0.26 MB) + chunks/state (~0.1 MB) — well under budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                state_ref, *, chunk: int):
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # (1, hd) bonus

    lw = jnp.cumsum(w, axis=0)                # (C, hd) inclusive
    lw_prev = lw - w                          # exclusive
    S = state_ref[...]                        # (hd, hd)

    y_cross = jax.lax.dot_general(r * jnp.exp(lw_prev), S,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # exact pairwise intra-chunk decays: exponent lw_prev[t]-lw[tau] <= 0 for tau<t
    ldiff = lw_prev[:, None, :] - lw[None, :, :]                  # (C,C,hd)
    prod = (r[:, None, :] * k[None, :, :]) * jnp.exp(ldiff)
    A = jnp.sum(prod, axis=-1)                                    # (C,C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(tj < ti, A, 0.0)
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)             # (C,1)
    y_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32) + diag * v

    y_ref[0] = (y_cross + y_intra).astype(y_ref.dtype)

    k_tail = k * jnp.exp(lw[-1:] - lw)        # exponent <= 0
    state_ref[...] = (jnp.exp(lw[-1])[:, None] * S
                      + jax.lax.dot_general(k_tail, v, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    @pl.when(t == nt - 1)
    def _emit_state():
        sout_ref[0] = state_ref[...]


def wkv6(r, k, v, w, u, state, *, chunk: int = 32, interpret: bool = False):
    """r,k,v,w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) f32 -> (y, state')."""
    B, T, H, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nt = T // chunk

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, hd)

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    s0 = state.reshape(B * H, hd, hd).astype(jnp.float32)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, t: (bh, t, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, t: (bh, t, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, t: (bh, t, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, t: (bh, t, 0)),
            pl.BlockSpec((1, 1, hd), lambda bh, t: (bh, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, t: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, t: (bh, t, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, t: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T, hd), r.dtype),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)
    y = y.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, hd, hd)
