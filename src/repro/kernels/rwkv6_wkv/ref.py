"""Pure-jnp oracle for the RWKV-6 WKV recurrence (sequential scan)."""
from repro.layers.rwkv6 import wkv6_ref  # noqa: F401  (the oracle lives with the layer)
