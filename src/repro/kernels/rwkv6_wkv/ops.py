"""Jit'd public wrapper for the chunked RWKV-6 WKV kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6_wkv.kernel import wkv6 as _kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, state, chunk: int = 64):
    return _kernel(r, k, v, w, u, state, chunk=chunk, interpret=_on_cpu())
