"""Elastic scaling + straggler mitigation policies.

Checkpoints store *logical* (unsharded) arrays, so elastic re-scaling is
re-sharding at load: ``reshard_for_mesh`` places a restored tree onto any
mesh under the framework's sharding rules — a 512-chip checkpoint restarts on
256 chips (or 1024) with no format conversion. The deterministic data stream
(training/data.py) is keyed by (seed, step, shard), so a changed shard count
re-partitions the stream consistently.

Straggler mitigation: ``RebalancePolicy`` consumes per-shard step times and
emits data-parallel bucket weights — slow hosts get proportionally smaller
microbatch shares (gradient contributions are re-weighted by actual token
counts, so the estimator stays unbiased).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig


def reshard_for_mesh(tree, mesh, rules_fn):
    """Place a (host-resident) pytree onto `mesh` using per-leaf specs from
    rules_fn(path, leaf) -> PartitionSpec."""
    from jax.sharding import NamedSharding
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = rules_fn(path, leaf)
        out.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class RebalancePolicy:
    """Weighted DP bucket assignment from observed shard step-times."""
    n_shards: int
    smoothing: float = 0.5
    min_share: float = 0.25
    _ema: Optional[np.ndarray] = None

    def update(self, shard_times: List[float]) -> np.ndarray:
        t = np.asarray(shard_times, np.float64)
        self._ema = t if self._ema is None else \
            self.smoothing * self._ema + (1 - self.smoothing) * t
        speed = 1.0 / np.maximum(self._ema, 1e-9)
        share = speed / speed.sum() * self.n_shards
        share = np.maximum(share, self.min_share)
        return share / share.sum()

    def bucket_sizes(self, global_batch: int, shard_times: List[float]
                     ) -> List[int]:
        share = self.update(shard_times)
        sizes = np.floor(share * global_batch).astype(int)
        sizes = np.maximum(sizes, 1)
        # distribute the remainder to the fastest shards
        rem = global_batch - sizes.sum()
        order = np.argsort(-share)
        for i in range(abs(int(rem))):
            sizes[order[i % self.n_shards]] += int(np.sign(rem))
        return sizes.tolist()
