"""Sharded, atomic, async checkpointing with restart discovery.

Layout:  <dir>/step_<N>/
            manifest.json        {leaf path -> {shape, dtype, file}}
            <leaf>.bin           raw bytes (per-host shard slice at scale)
            COMMITTED            written last -> crash-safe atomicity marker

Restart: ``latest_step`` ignores directories without the COMMITTED marker, so
a checkpoint truncated by a node failure is never restored. Saves can run on
a background thread (async_save) so the train loop is not blocked — the tree
is snapshotted to host memory synchronously (cheap) and written asynchronously
(the slow part), the standard large-scale pattern.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_COMMIT = "COMMITTED"


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def save(tree, directory: str, step: int):
    tmp = os.path.join(directory, f"_tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = re.sub(r"[^A-Za-z0-9_.-]", "_", name) + ".bin"
        arr.tofile(os.path.join(tmp, fn))
        manifest[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                          "file": fn}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def restore(template, directory: str, step: int):
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        meta = manifest[name]
        arr = np.fromfile(os.path.join(d, meta["file"]),
                          dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, _COMMIT)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def gc_old(directory: str, keep: int = 3):
    if not os.path.isdir(directory):
        return
    steps = sorted([int(m.group(1)) for d in os.listdir(directory)
                    if (m := re.fullmatch(r"step_(\d+)", d))])
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, tree, step: int):
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(snapshot, step), daemon=True)
        self._thread.start()

    def _write(self, snapshot, step):
        save(snapshot, self.directory, step)
        gc_old(self.directory, self.keep)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
