"""Optimizers and LR schedules, from scratch (no optax in this container).

AdamW with decoupled weight decay, global-norm clipping, and mixed precision:
bf16 params + f32 master copies / moments (the standard large-scale recipe).
Schedules: linear warmup -> cosine, and WSD (warmup-stable-decay) — the
MiniCPM schedule its assigned config calls for.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Any          # f32 master params (None when params already f32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    f32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(jnp.zeros_like, f32)
    needs_master = any(p.dtype != jnp.float32 for p in jax.tree.leaves(params))
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.zeros_like, f32),
                      f32 if needs_master else None)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig
                 ) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    master = state.master if state.master is not None else params

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step)
        vhat = v / (1 - cfg.b2 ** step)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p32)
        return m, v, p32

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(master)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    p32 = jax.tree.unflatten(treedef, [o[2] for o in out])

    if state.master is not None:
        new_params = jax.tree.map(lambda p32, p: p32.astype(p.dtype), p32, params)
        new_state = AdamWState(step, mu, nu, p32)
    else:
        new_params = p32
        new_state = AdamWState(step, mu, nu, None)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------
def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM): flat plateau, sharp final decay."""
    decay_start = int(total * (1 - decay_frac))

    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        dec = peak_lr * jnp.exp(jnp.log(final_frac) * prog)
        flat = jnp.where(step >= decay_start, dec, peak_lr)
        return jnp.where(step < warmup, warm, flat)
    return lr
