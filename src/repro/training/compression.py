"""Gradient compression: int8 quantization with error feedback.

``compress``/``decompress`` quantize per-tensor with a shared absmax scale;
the residual is carried in an error-feedback buffer so the *accumulated*
quantization error stays bounded (the EF-SGD guarantee) — quantized training
then converges to the same neighborhood as exact training.

``compressed_psum`` is the distributed hook: inside a shard_map'd train step
the gradient all-reduce runs on int8 payloads (4x less ICI traffic than f32,
8x less than... well, bf16 is 2x) and dequantizes after the sum. Used by the
collective-bound hillclimb experiments in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedGrad(NamedTuple):
    q: jnp.ndarray       # int8 payload
    scale: jnp.ndarray   # f32 per-tensor scale


def compress(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[CompressedGrad, jnp.ndarray]:
    """Quantize (g + err) to int8; return payload and the new error buffer."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return CompressedGrad(q, scale), new_err


def decompress(c: CompressedGrad) -> jnp.ndarray:
    return c.q.astype(jnp.float32) * c.scale


def init_error_buffers(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, err_tree):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    comps, errs = zip(*(compress(g, e) for g, e in zip(flat_g, flat_e)))
    return (jax.tree.unflatten(treedef, comps),
            jax.tree.unflatten(treedef, errs))


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 all-reduce mean with error feedback (call inside shard_map).

    Integer payloads from different workers can only be summed if they share
    one scale, so the workers first agree on the max scale (a scalar pmax —
    negligible traffic), quantize against it, then psum the int8 payload in
    int32 (no overflow for <= 2^23 workers). Error feedback absorbs the
    coarser shared-scale quantization on workers with small gradients.
    """
    x = g.astype(jnp.float32) + err
    local_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return qsum.astype(jnp.float32) * scale / n, new_err
