"""Jit'd train step with microbatch gradient accumulation, remat, and the
fault-tolerant outer loop (checkpoint/restart, failure injection hooks,
straggler monitor).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)


@dataclass
class TrainConfig:
    steps: int = 100
    micro_batches: int = 1
    remat: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    # sharding constraint axes for the sharded loss ({"dp": (...), "tp": "model"});
    # None on unsharded CPU runs
    shard_axes: Optional[dict] = None


def make_train_step(mcfg: ModelConfig, ocfg: AdamWConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, stats).
    With micro_batches > 1 the batch's leading dim is split and gradients are
    accumulated in a lax.scan (constant memory in the number of microbatches).
    """

    def loss_fn(p, mb):
        return api.loss_fn(p, mcfg, mb, remat=tcfg.remat,
                           shard_axes=tcfg.shard_axes)

    def train_step(params, opt_state: AdamWState, batch):
        if tcfg.micro_batches > 1:
            n = tcfg.micro_batches
            mbs = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, stats = adamw_update(grads, opt_state, params, ocfg)
        stats = dict(stats, loss=loss)
        return params, opt_state, stats

    return train_step


@dataclass
class StragglerMonitor:
    """Tracks per-step times; flags steps slower than k x the running median.
    At scale the same policy consumes per-host collective timings; the
    mitigation hook re-balances data-parallel buckets away from the slow host
    (see training/elastic.py)."""
    factor: float = 3.0
    window: int = 32
    times: list = field(default_factory=list)
    flags: int = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = sorted(hist)[len(hist) // 2]
        slow = len(hist) >= 8 and dt > self.factor * med
        self.flags += int(slow)
        return slow


def train(mcfg: ModelConfig, dcfg: DataConfig, ocfg: AdamWConfig,
          tcfg: TrainConfig, *, seed: int = 0,
          fail_at: Optional[int] = None,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict[str, Any]:
    """Fault-tolerant training driver.

    Restart semantics: on entry, if ckpt_dir holds a COMMITTED checkpoint we
    resume from it (params+opt+step); the deterministic data pipeline replays
    from the restored step. ``fail_at`` injects a crash for the restart tests.
    """
    hooks = hooks or {}
    params = api.init_params(jax.random.PRNGKey(seed), mcfg)
    opt_state = adamw_init(params, ocfg)
    start = 0
    saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir, tcfg.keep) if tcfg.ckpt_dir else None

    if tcfg.ckpt_dir and (last := ckpt.latest_step(tcfg.ckpt_dir)) is not None:
        state = ckpt.restore({"params": params, "opt": opt_state},
                             tcfg.ckpt_dir, last)
        params, opt_state = state["params"], state["opt"]
        start = last

    step_fn = jax.jit(make_train_step(mcfg, ocfg, tcfg))
    monitor = StragglerMonitor()
    losses = []
    for step in range(start, tcfg.steps):
        if fail_at is not None and step == fail_at:
            if saver:
                saver.wait()
            raise RuntimeError(f"injected node failure at step {step}")
        batch = make_batch(dcfg, mcfg, step)
        t0 = time.monotonic()
        params, opt_state, stats = step_fn(params, opt_state, batch)
        loss = float(stats["loss"])
        monitor.observe(time.monotonic() - t0)
        losses.append(loss)
        if "on_step" in hooks:
            hooks["on_step"](step, stats)
        if saver and (step + 1) % tcfg.ckpt_every == 0:
            saver.save({"params": params, "opt": opt_state}, step + 1)
    if saver:
        saver.wait()
    return {"params": params, "opt": opt_state, "losses": losses,
            "straggler_flags": monitor.flags}
