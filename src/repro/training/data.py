"""Deterministic synthetic data pipeline.

Seeded, shardable, restart-reproducible: batch `i` is a pure function of
(seed, step, shard), so checkpoint-restart resumes the exact stream with no
stored iterator state — the property the fault-tolerance driver relies on.
The token stream is a Zipfian-ish mixture with local n-gram structure so
losses decrease meaningfully during the example runs (pure-uniform tokens
would pin the loss at log V).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ENCDEC, ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    n_shards: int = 1
    shard: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard]))


def synthetic_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-weighted markov-ish stream: next token correlates with previous."""
    v_eff = min(vocab, 4096)
    base = rng.zipf(1.3, size=shape) % v_eff
    prev = np.roll(base, 1, axis=-1)
    mix = rng.random(shape) < 0.35
    out = np.where(mix, (prev * 31 + 7) % v_eff, base)
    return out.astype(np.int32)


def make_batch(cfg: DataConfig, mcfg: ModelConfig, step: int) -> Dict:
    rng = _batch_rng(cfg, step)
    b = cfg.batch // cfg.n_shards
    t_text = cfg.seq_len - mcfg.n_prefix_embeds
    batch = {"tokens": jnp.asarray(
        synthetic_tokens(rng, (b, t_text), mcfg.vocab_size))}
    if mcfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((b, mcfg.n_prefix_embeds, mcfg.d_model)) * 0.02,
            mcfg.compute_dtype)
    if mcfg.family == ENCDEC:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, mcfg.encdec.encoder_seq_len, mcfg.d_model)) * 0.02,
            mcfg.compute_dtype)
    return batch


def data_stream(cfg: DataConfig, mcfg: ModelConfig,
                start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield make_batch(cfg, mcfg, step)
        step += 1
