"""Mesh-real memory tiers: donor page pools resident on PEER mesh devices.

This is the step from *simulated* AQUA to AQUA: the REMOTE tier stops being
an analytic fiction (a device-local array priced as if it crossed a fabric)
and becomes a slab of a peer device's memory on a real ``jax`` device mesh.

``MeshTierDomain`` owns a 1-D mesh over the scale-up domain (the paper's
8-GPU NVLink clique; here every addressable jax device — on the CPU CI box a
forced host-platform device mesh, on real hardware the ICI/NVLink ring).
Device 0 is the SERVING chip; every other device is a potential donor.
A donor lease (``AquaTensor.add_remote_lease``) allocates an actual pool
sharded so the donor's slab lives on the donor device, and the two transfer
legs lower to collectives:

  push (offload / park)    stage the coalesced page batch on the serving
                           shard, ONE ``jax.lax.ppermute`` to the donor
                           shard, scatter into the donor's pool slab
  pull (ensure_local)      gather the requested slots on the donor shard,
                           ONE ``ppermute`` back to the serving shard

Both legs run inside a single ``shard_map`` program per (bucket, pool-shape)
key, so each (plane, tier, donor) leg of a tier flip is exactly one
collective message on the wire — the physical counterpart of the
``TransferMeter`` coalescing invariant (``collectives`` counts them, tests
assert one per leg). Page counts pad to power-of-two buckets so the jit
cache stays flat however many pages a request parks.

Every warm leg is wall-clocked (``block_until_ready``; the first call per
compiled key is compile time and is skipped), and the samples feed
``perfmodel.fit_link_model`` / ``calibrate_profile`` — the analytic clock
(``page_flip_time``, ``TransferMeter`` pricing) is thereby calibrated
against MEASURED mesh transfers instead of datasheet constants
(``ServingEngine.calibrate_clock``).

Host staging exists only on the HOST leg (``AquaTensor`` keeps its numpy
host pool); fabric legs never bounce through host memory.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.errors import LeaseRevokedError
from repro.distributed.sharding import shard_map_compat


def _bucket(n: int) -> int:
    """Power-of-two shape bucket for a page-batch length (min 1)."""
    b = 1
    while b < n:
        b *= 2
    return b


class MeshTierDomain:
    """A scale-up domain: one serving device plus donor peers on a 1-D mesh.

    The domain is shared by every plane's :class:`~repro.core.aqua_tensor.
    AquaTensor` of a serving runtime: it owns the donor name -> device
    mapping (stable across evict/re-lease cycles), the compiled transfer
    legs, the collective counter, and the measured-transfer sample log.
    """

    def __init__(self, devices: Optional[Sequence] = None, *,
                 axis: str = "fabric"):
        """Build the domain over ``devices`` (default: every jax device).

        Raises:
            ValueError: fewer than 2 devices (no peer to donate HBM) or a
                multi-process mesh (single-controller only — the serving
                process must address every donor shard directly).
        """
        devices = list(devices) if devices is not None else list(jax.devices())
        if jax.process_count() > 1:
            raise ValueError("mesh tiers need a single-process mesh: the "
                             "serving process must address donor shards "
                             "directly")
        if len(devices) < 2:
            raise ValueError(f"mesh tiers need >= 2 devices (got "
                             f"{len(devices)}): a donor lease is a slab of a "
                             "PEER device's memory")
        self.axis = axis
        self.devices = devices
        self.n_dev = len(devices)
        self.mesh = Mesh(np.array(devices), (axis,))
        self._donor_dev: Dict[str, int] = {}
        # optional core/faults.FaultInjector shared with the AquaTensors of
        # this domain: the domain double-checks lost donors at its own
        # boundary (a collective addressed to a dead peer must never be
        # issued, whatever the caller's bookkeeping says)
        self.faults = None
        # one entry per physical collective issued (one per (plane, tier,
        # donor) leg) — the wire-message counterpart of the TransferMeter's
        # priced messages
        self.collectives = 0
        # measured (message_bytes, seconds) per warm fabric leg
        self.samples: Dict[str, List[Tuple[float, float]]] = {"fabric": []}
        self._push_cache: Dict[tuple, object] = {}
        self._pull_cache: Dict[tuple, object] = {}
        self._zero_cache: Dict[tuple, list] = {}
        self._warm: set = set()

    # ------------------------------------------------------------------
    @staticmethod
    def available(min_devices: int = 2) -> bool:
        """True when a domain can be built here — the tier-1 skip guard
        (single process, at least one peer device)."""
        try:
            return (jax.process_count() == 1
                    and len(jax.devices()) >= min_devices)
        except RuntimeError:
            return False

    def attach_faults(self, faults) -> None:
        """Share a ``FaultInjector`` with the domain (lease-boundary checks
        on every collective leg; the AquaTensors consult the same injector
        for transient-leg retries BEFORE reaching these entry points)."""
        self.faults = faults

    def _guard_donor(self, donor: str, op: str) -> None:
        if self.faults is not None and self.faults.donor_lost(donor):
            raise LeaseRevokedError(
                f"mesh {op} addressed lost donor {donor} — its device left "
                "the domain", donor=donor)

    def donor_device(self, donor: str) -> int:
        """Mesh index of the device backing ``donor``'s leases. Assigned on
        first use, cycling over the peers (device 0 serves), and STABLE for
        the donor's lifetime — an evicted donor that re-leases lands on the
        same device."""
        if donor not in self._donor_dev:
            self._donor_dev[donor] = 1 + len(self._donor_dev) % (self.n_dev - 1)
        return self._donor_dev[donor]

    # ------------------------------------------------------------------
    # pool + transfer legs (called by AquaTensor's remote helpers)
    # ------------------------------------------------------------------
    def alloc_pool(self, donor: str, slots: int, page_shape: Tuple[int, ...],
                   dtype) -> jax.Array:
        """A donor lease as a REAL slab: a zeroed ``(n_dev, slots+1, *page)``
        array sharded over the fabric axis, so row ``donor_device(donor)``
        — the only row ever read or written — is resident on the donor
        device. Slot ``slots`` is the scatter scratch row bucket padding
        targets."""
        self._guard_donor(donor, "lease")
        self.donor_device(donor)              # pin the mapping at lease time
        shape = (self.n_dev, slots + 1) + tuple(page_shape)
        sharding = NamedSharding(self.mesh, P(self.axis))
        return jax.device_put(jnp.zeros(shape, dtype), sharding)

    def push(self, pool: jax.Array, donor: str, slots: np.ndarray,
             data: jnp.ndarray) -> jax.Array:
        """Offload leg: move ``data`` (a coalesced page batch on the serving
        device) into ``pool``'s donor slab at ``slots`` — ONE ppermute.
        Returns the updated pool."""
        self._guard_donor(donor, "push")
        dst = self.donor_device(donor)
        n = len(slots)
        S = pool.shape[1] - 1
        page_shape = tuple(pool.shape[2:])
        dtype = pool.dtype
        b = _bucket(n)
        slots = np.asarray(slots, np.int32)
        data = jnp.asarray(data, dtype)
        if b > n:                             # pad to the bucket: scratch row
            slots = np.concatenate([slots, np.full(b - n, S, np.int32)])
            data = jnp.concatenate(
                [data, jnp.zeros((b - n,) + page_shape, dtype)], axis=0)
        fn, key = self._push_fn(dst, b, S, page_shape, str(dtype))
        stage = self._stage(data, b, page_shape, dtype)
        out, dt = self._timed(fn, pool, stage, jnp.asarray(slots))
        self._account(key, b * int(np.prod(page_shape)) * dtype.itemsize, dt)
        return out

    def pull(self, pool: jax.Array, donor: str,
             slots: np.ndarray) -> jnp.ndarray:
        """Restore leg: gather ``slots`` from the donor slab and move them to
        the serving device — ONE ppermute. Returns the ``(n, *page)`` staging
        batch committed to the serving device."""
        self._guard_donor(donor, "pull")
        src = self.donor_device(donor)
        n = len(slots)
        S = pool.shape[1] - 1
        page_shape = tuple(pool.shape[2:])
        b = _bucket(n)
        slots = np.asarray(slots, np.int32)
        if b > n:                             # padded gathers are discarded
            slots = np.concatenate([slots, np.zeros(b - n, np.int32)])
        fn, key = self._pull_fn(src, b, S, page_shape, str(pool.dtype))
        out, dt = self._timed(fn, pool, jnp.asarray(slots))
        self._account(key, b * int(np.prod(page_shape)) * pool.dtype.itemsize,
                      dt)
        for shard in out.addressable_shards:
            if shard.device == self.devices[0]:
                return shard.data[0, :n]
        raise RuntimeError("serving device shard missing from pull output")

    # ------------------------------------------------------------------
    def _timed(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        out.block_until_ready()
        return out, time.perf_counter() - t0

    def _account(self, key: tuple, nbytes: int, dt: float):
        self.collectives += 1
        if key in self._warm:                 # first call per key = compile
            self.samples["fabric"].append((float(nbytes), float(dt)))
        else:
            self._warm.add(key)

    def _push_fn(self, dst: int, bucket: int, S: int,
                 page_shape: Tuple[int, ...], dtype_str: str):
        key = ("push", dst, bucket, S, page_shape, dtype_str)
        fn = self._push_cache.get(key)
        if fn is None:
            axis = self.axis

            def step(pool_s, stage_s, slots):
                # pool_s (1, S+1, *page), stage_s (1, bucket, *page): this
                # device's shards; slots replicated. One collective moves the
                # staged batch serving -> donor; only the donor keeps the
                # scattered update (everyone else's shard passes through).
                moved = jax.lax.ppermute(stage_s, axis, [(0, dst)])
                upd = pool_s[0].at[slots].set(moved[0])
                keep = jax.lax.axis_index(axis) == dst
                return jnp.where(keep, upd, pool_s[0])[None]

            fn = jax.jit(shard_map_compat(
                step, self.mesh, (P(axis), P(axis), P()), P(axis),
                check=False))
            self._push_cache[key] = fn
        return fn, key

    def _pull_fn(self, src: int, bucket: int, S: int,
                 page_shape: Tuple[int, ...], dtype_str: str):
        key = ("pull", src, bucket, S, page_shape, dtype_str)
        fn = self._pull_cache.get(key)
        if fn is None:
            axis = self.axis

            def step(pool_s, slots):
                # gather is cheap on every shard; only the donor's rows are
                # real, and one collective moves them donor -> serving
                # (non-addressed shards receive zeros per ppermute semantics)
                stage = pool_s[0][slots]
                return jax.lax.ppermute(stage[None], axis, [(src, 0)])

            fn = jax.jit(shard_map_compat(
                step, self.mesh, (P(axis), P()), P(axis), check=False))
            self._pull_cache[key] = fn
        return fn, key

    def _stage(self, data: jnp.ndarray, bucket: int,
               page_shape: Tuple[int, ...], dtype) -> jax.Array:
        """Assemble the push operand: the real batch as the serving shard,
        cached zero shards for every peer (building the global array from
        per-device pieces keeps the staging traffic at ONE message — a
        replicated operand would broadcast the payload to all peers)."""
        shape = (self.n_dev, bucket) + page_shape
        sharding = NamedSharding(self.mesh, P(self.axis))
        zkey = (bucket, page_shape, str(jnp.dtype(dtype)))
        zeros = self._zero_cache.get(zkey)
        if zeros is None:
            zeros = [jax.device_put(jnp.zeros((1, bucket) + page_shape, dtype),
                                    d) for d in self.devices[1:]]
            self._zero_cache[zkey] = zeros
        first = jax.device_put(data[None], self.devices[0])
        return jax.make_array_from_single_device_arrays(
            shape, sharding, [first] + zeros)

    # ------------------------------------------------------------------
    def calibrated_profile(self, hw, *, min_samples: int = 4):
        """A copy of ``hw`` whose fabric link is least-squares fitted to the
        measured push/pull samples (``perfmodel.calibrate_profile``); ``hw``
        itself when there are not yet enough samples to fit."""
        from repro.core.perfmodel import calibrate_profile
        return calibrate_profile(hw, fabric_samples=self.samples["fabric"],
                                 min_samples=min_samples)
