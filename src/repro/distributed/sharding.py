"""Per-tensor sharding rules with divisibility fallbacks.

The rules are name-aware where it matters (attention in/out projections, MoE
expert stacks, embeddings) and fall back to a size-greedy auto-sharder
everywhere else. Every rule checks divisibility against the mesh axis size
and degrades to replication rather than failing — a config change must never
break lowering (large-scale runnability requirement).

Conventions (see DESIGN.md §7):
  * batch-bearing inputs shard over ("pod","data")
  * weight matrices: input-features x output-features -> P(fsdp, "model") for
    in-projections, P("model", fsdp) for out-projections (keeps the TP
    all-reduce at the residual, Megatron-style)
  * MoE expert stacks (E, d, f): expert axis over "model" (EP) when divisible
  * KV caches: batch over dp; kv-head over "model" when divisible, else
    sequence over "model" (flash-decoding style), else replicate
  * scan-stacked params carry a leading group axis that is never sharded
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def shard_map_compat(f, mesh, in_specs, out_specs, *, check: bool = True):
    """``shard_map`` across jax versions: it lived in
    ``jax.experimental.shard_map`` (kwarg ``check_rep``) before being
    promoted to ``jax.shard_map`` (kwarg ``check_vma``).

    Serving-side consumer: ``distributed/mesh_tiers.py`` wraps every
    mesh-tier transfer leg (one ``ppermute`` each) in this, with
    ``check=False`` — the legs are deliberately non-replicated (only the
    serving/donor shard carries real data)."""
    import inspect
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check})


# parameter-name classes
_IN_PROJ = ("wq", "wk", "wv", "up", "gate", "mix_w1", "decay_w1", "in_proj",
            "x_proj", "wdkv", "wuk", "wuv", "q_a", "v_a")
_OUT_PROJ = ("wo", "down", "out_proj", "mix_w2", "decay_w2", "dt_proj",
             "q_b", "v_b")


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class ShardingRules:
    def __init__(self, mesh, cfg: ModelConfig, *, fsdp: bool = True):
        self.mesh = mesh
        self.cfg = cfg
        self.fsdp = fsdp
        self.model_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        self.data_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
        self.dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        self.dp_n = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                                 for a in self.dp])) if self.dp else 1

    # ------------------------------------------------------------------
    def param_spec(self, path, leaf) -> P:
        names = _path_names(path)
        shape = leaf.shape
        nd = len(shape)
        # scan-stacked params: leading group axis — shard the rest
        skip = 1 if (names and names[0] in ("blocks", "enc_blocks", "dec_blocks")
                     and nd >= 2) else 0
        dims = list(range(skip, nd))
        spec: list = [None] * nd
        if not dims:
            return P()
        leafname = names[-1] if names[-1] != "w" and names[-1] != "b" else names[-2]

        # expert stacks (G, E, d, f) / (E, d, f): expert axis -> model (EP)
        if leafname in ("up", "down", "gate") and nd - skip == 3:
            e_dim = dims[0]
            if _div(shape[e_dim], self.model_n):
                spec[e_dim] = "model"
                if self.fsdp and _div(shape[e_dim + 1], self.data_n):
                    spec[e_dim + 1] = "data"
                return P(*spec)
        # embeddings: vocab x d_model
        if leafname in ("tok", "head"):
            big = max(dims, key=lambda i: shape[i])
            if _div(shape[big], self.model_n):
                spec[big] = "model"
            other = [i for i in dims if i != big]
            if self.fsdp and other and _div(shape[other[0]], self.data_n):
                spec[other[0]] = "data"
            return P(*spec)
        if nd - skip == 2:
            i, o = dims[0], dims[1]
            if leafname in _IN_PROJ:
                tp, fs = o, i
            elif leafname in _OUT_PROJ:
                tp, fs = i, o
            else:
                tp, fs = (o, i) if shape[o] >= shape[i] else (i, o)
            if _div(shape[tp], self.model_n):
                spec[tp] = "model"
            if self.fsdp and _div(shape[fs], self.data_n):
                spec[fs] = "data"
            return P(*spec)
        # 1-D (biases, norms) and small leftovers: replicate; fsdp big vectors
        if nd - skip == 1 and self.fsdp and shape[dims[0]] >= 1 << 16 \
                and _div(shape[dims[0]], self.data_n):
            spec[dims[0]] = "data"
        return P(*spec)

    def params(self, param_tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(param_tree)
        return jax.tree_util.tree_unflatten(
            treedef, [NamedSharding(self.mesh, self.param_spec(p, l))
                      for p, l in flat])

    # ------------------------------------------------------------------
    def cache_spec(self, path, leaf, batch: int) -> P:
        shape = leaf.shape
        nd = len(shape)
        names = _path_names(path)
        leafname = names[-1] if names else ""
        spec: list = [None] * nd
        # caches are stacked (G/L, B, ...): dim1 = batch
        bdim = 1 if nd >= 2 and shape[1] == batch else None
        if bdim is not None and _div(batch, self.dp_n):
            spec[bdim] = self.dp
        # one axis over "model". For k/v caches (G,B,S,K,hd) the order is
        # kv-heads -> sequence -> NEVER head_dim (sharding the attention
        # contraction dim forces layout churn + full-cache copies per step:
        # HC3 in EXPERIMENTS.md §Perf). Latent caches (MLA c_kv, rwkv state)
        # prefer their trailing feature dim (contraction-parallel decode).
        # (NamedTuple fields flatten to index keys, so dispatch on rank:
        # rank-5 leaves are (G,B,S,K,hd) k/v caches or (G,B,H,hd,hd) rwkv
        # states — dim 3 is the kv-head / outer-product-row dim in both.)
        if nd >= 5:
            order = [3, 2]
        else:
            order = [nd - 1, 2] if nd >= 3 else list(range(2, nd))
        for d in order:
            if 2 <= d < nd and spec[d] is None and _div(shape[d], self.model_n):
                spec[d] = "model"
                break
        return P(*spec)

    def cache(self, cache_tree, batch: int):
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
        return jax.tree_util.tree_unflatten(
            treedef, [NamedSharding(self.mesh, self.cache_spec(p, l, batch))
                      for p, l in flat])

    # ------------------------------------------------------------------
    def batch_spec(self, leaf, batch: int) -> P:
        nd = len(leaf.shape)
        if nd >= 1 and leaf.shape[0] == batch and _div(batch, self.dp_n):
            return P(self.dp, *([None] * (nd - 1)))
        return P(*([None] * nd))

    def batch(self, tree, batch: int):
        return jax.tree.map(
            lambda l: NamedSharding(self.mesh, self.batch_spec(l, batch)), tree)

    # ------------------------------------------------------------------
    def opt_state(self, opt_template, param_tree):
        """Optimizer moments/master mirror the param specs; step is replicated."""
        pspecs = self.params(param_tree)

        def build(field):
            if field is None:
                return None
            return jax.tree.map(lambda l, s: s, field, pspecs)

        from repro.training.optimizer import AdamWState
        return AdamWState(
            NamedSharding(self.mesh, P()),
            build(opt_template.mu), build(opt_template.nu),
            build(opt_template.master))
