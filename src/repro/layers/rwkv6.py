"""RWKV-6 "Finch" layers: time-mix with data-dependent decay + channel-mix.

Recurrence (per head, head_dim hd, state S in R^{hd x hd}):
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T          (w_t = -exp(...) < 0)

The XLA path runs a ``lax.scan`` over time (sequential; small HLO, trip-count
accounted by the roofline parser). The TPU-target chunked kernel lives in
``repro.kernels.rwkv6_wkv`` and is validated against :func:`wkv6_ref` here.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.core import init_linear, linear, trunc_normal


class RWKVState(NamedTuple):
    wkv: jnp.ndarray       # (B, H, hd, hd)
    tm_shift: jnp.ndarray  # (B, d)  previous token (time-mix)
    cm_shift: jnp.ndarray  # (B, d)  previous token (channel-mix)


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    hd = cfg.ssm.rwkv_head_dim
    H = cfg.d_model // hd
    return RWKVState(
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, cfg.d_model), dtype),
        jnp.zeros((batch, cfg.d_model), dtype),
    )


def init_rwkv_layer(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    hd = s.rwkv_head_dim
    H = d // hd
    dt = cfg.dtype()
    ks = jax.random.split(key, 12)
    return {
        "tm": {
            "mu_x": jnp.zeros((d,), dt),
            "maa": jnp.zeros((5, d), dt),
            "mix_w1": trunc_normal(ks[0], (d, 5 * s.rwkv_lora_mix), 0.02, dt),
            "mix_w2": trunc_normal(ks[1], (5, s.rwkv_lora_mix, d), 0.02, dt),
            "w0": jnp.full((d,), -6.0, dt),
            "decay_w1": trunc_normal(ks[2], (d, s.rwkv_lora_decay), 0.02, dt),
            "decay_w2": trunc_normal(ks[3], (s.rwkv_lora_decay, d), 0.02, dt),
            "u": trunc_normal(ks[4], (H, hd), 0.02, dt),
            "wr": init_linear(ks[5], d, d, dt),
            "wk": init_linear(ks[6], d, d, dt),
            "wv": init_linear(ks[7], d, d, dt),
            "wg": init_linear(ks[8], d, d, dt),
            "wo": init_linear(ks[9], d, d, dt),
            "ln_x": jnp.zeros((d,), dt),
        },
        "cm": {
            "mu_k": jnp.zeros((d,), dt),
            "mu_r": jnp.zeros((d,), dt),
            "wk": init_linear(ks[10], d, cfg.d_ff, dt),
            "wv": init_linear(jax.random.fold_in(ks[10], 1), cfg.d_ff, d, dt),
            "wr": init_linear(ks[11], d, d, dt),
        },
    }


# ---------------------------------------------------------------------------
# WKV recurrence (pure-jnp oracle; kernels/rwkv6_wkv implements the chunked form)
# ---------------------------------------------------------------------------
def wkv6_ref(r, k, v, w, u, state):
    """r,k,v,w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) -> (y, state')."""
    def step2(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)     # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = jnp.exp(w_t.astype(jnp.float32))[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step2, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), state


WKV_CHUNK = 32


def wkv6_chunked(r, k, v, w, u, state, chunk: int = WKV_CHUNK):
    """Chunked WKV — the same algorithm as kernels/rwkv6_wkv, in pure jnp.

    Per chunk of C tokens: one (C,hd)x(hd,hd) state matmul, one exact-pairwise
    (C,C,hd) intra-chunk decay tensor, one (C,C)x(C,hd) combine, one
    (hd,C)x(C,hd) state update. vs. the per-token scan this raises arithmetic
    intensity onto the MXU and cuts HBM round-trips by ~C (the §Perf HC1
    iteration: t_memory 2868 s -> see EXPERIMENTS.md). Every materialized
    exponent is <= 0 (stability invariant shared with the kernel).
    """
    B, T, H, hd = r.shape
    chunk = min(chunk, T)
    if T % chunk != 0:
        return wkv6_ref(r, k, v, w, u, state)
    nt = T // chunk

    def fold(x):
        return (x.astype(jnp.float32).transpose(0, 2, 1, 3)
                .reshape(B * H, nt, chunk, hd))

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    S0 = state.reshape(B * H, hd, hd).astype(jnp.float32)
    ti = jnp.arange(chunk)
    tri = (ti[None, :] < ti[:, None]).astype(jnp.float32)         # strict lower

    def step(S, inp):
        rc, kc, vc, wc = inp                                      # (BH,C,hd)
        lw = jnp.cumsum(wc, axis=1)
        lw_prev = lw - wc
        y_cross = jnp.einsum("bch,bhj->bcj", rc * jnp.exp(lw_prev), S)
        ldiff = lw_prev[:, :, None, :] - lw[:, None, :, :]        # (BH,C,C,hd)
        A = jnp.sum((rc[:, :, None] * kc[:, None]) * jnp.exp(ldiff), -1) * tri
        diag = jnp.sum(rc * uf * kc, -1, keepdims=True)
        y = y_cross + jnp.einsum("bct,bth->bch", A, vc) + diag * vc
        k_tail = kc * jnp.exp(lw[:, -1:] - lw)
        S = (jnp.exp(lw[:, -1])[..., None] * S
             + jnp.einsum("bch,bcj->bhj", k_tail, vc))
        return S, y

    S, ys = jax.lax.scan(step, S0, tuple(jnp.moveaxis(a, 1, 0)
                                         for a in (rf, kf, vf, wf)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B * H, T, hd)
    y = y.reshape(B, H, T, hd).transpose(0, 2, 1, 3).astype(r.dtype)
    return y, S.reshape(B, H, hd, hd)


def _head_norm(scale, y, H, hd, eps=1e-5):
    B, T = y.shape[:2]
    yh = y.reshape(B, T, H, hd).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, T, H * hd) * (1.0 + scale.astype(jnp.float32))).astype(y.dtype)


def _tm_inputs(p, x, xx):
    """Data-dependent token-shift interpolation for (w,k,v,r,g)."""
    x_mix = x + xx * p["mu_x"].astype(x.dtype)
    B, T, d = x.shape
    mr = p["mix_w1"].shape[1] // 5
    mix = jnp.tanh(x_mix @ p["mix_w1"].astype(x.dtype)).reshape(B, T, 5, mr)
    lora = jnp.einsum("btfr,frd->btfd", mix, p["mix_w2"].astype(x.dtype))
    interp = p["maa"].astype(x.dtype)[None, None] + lora           # (B,T,5,d)
    return [x + xx * interp[:, :, i] for i in range(5)]


def _last_real_row(x, n_real):
    """Row ``n_real - 1`` of (B,T,d) — the shift state a bucket-padded chunk
    must carry (``x[:, -1]`` when n_real is None / the chunk is unpadded).
    ``n_real`` may be a per-lane ``(B,)`` vector (the fused packed step):
    each lane then carries its own last real row."""
    if n_real is None:
        return x[:, -1]
    if jnp.ndim(n_real) > 0:
        idx = (jnp.asarray(n_real, jnp.int32) - 1)[:, None, None]
        return jnp.take_along_axis(x, jnp.maximum(idx, 0), axis=1)[:, 0]
    return jax.lax.dynamic_slice_in_dim(x, n_real - 1, 1, axis=1)[:, 0]


def rwkv_time_mix(p, cfg: ModelConfig, x, shift_prev, wkv_state, *,
                  use_kernel=False, n_real=None):
    """x: (B,T,d). shift_prev: (B,d) hidden state of last token from prev chunk.

    ``n_real`` (traced scalar, or per-lane ``(B,)`` vector in the fused
    packed step) marks the last real row of a bucket-padded chunk: padded
    rows get ``w = 0`` (decay ``exp(0) = 1``) and ``k = 0`` (no
    kv outer-product update), so the carried wkv state after the chunk is
    bit-exactly the state after the last real token; the returned shift state
    is that token's row rather than the padding tail.
    """
    B, T, d = x.shape
    hd = cfg.ssm.rwkv_head_dim
    H = d // hd
    prev = jnp.concatenate([shift_prev[:, None], x[:, :-1]], axis=1)
    xx = prev - x
    xw, xk, xv, xr, xg = _tm_inputs(p, x, xx)

    logw = -jnp.exp(p["w0"].astype(jnp.float32)
                    + (jnp.tanh(xw @ p["decay_w1"].astype(x.dtype))
                       @ p["decay_w2"].astype(x.dtype)).astype(jnp.float32))
    r = linear(p["wr"], xr).reshape(B, T, H, hd)
    k = linear(p["wk"], xk).reshape(B, T, H, hd)
    v = linear(p["wv"], xv).reshape(B, T, H, hd)
    g = jax.nn.silu(linear(p["wg"], xg))
    w = logw.reshape(B, T, H, hd)
    if n_real is not None:
        nr = jnp.asarray(n_real, jnp.int32).reshape(-1, 1)     # (1|B, 1)
        m = (jnp.arange(T)[None, :] < nr)[:, :, None, None]
        k = k * m
        w = w * m

    if use_kernel:
        from repro.kernels.rwkv6_wkv import ops as wkv_ops
        y, wkv_state = wkv_ops.wkv6(r, k, v, w.astype(jnp.float32),
                                    p["u"].astype(jnp.float32), wkv_state)
    elif T >= 2 * WKV_CHUNK and T % WKV_CHUNK == 0:
        # chunked XLA path (same algorithm as the Pallas kernel): MXU-friendly
        y, wkv_state = wkv6_chunked(r, k, v, w, p["u"].astype(jnp.float32),
                                    wkv_state)
    else:
        y, wkv_state = wkv6_ref(r, k, v, w, p["u"].astype(jnp.float32), wkv_state)
    y = _head_norm(p["ln_x"], y.reshape(B, T, d), H, hd)
    out = linear(p["wo"], y * g)
    return out, _last_real_row(x, n_real), wkv_state


def rwkv_channel_mix(p, x, shift_prev, n_real=None):
    prev = jnp.concatenate([shift_prev[:, None], x[:, :-1]], axis=1)
    xx = prev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk)))
    out = jax.nn.sigmoid(linear(p["wr"], xr)) * linear(p["wv"], k)
    return out, _last_real_row(x, n_real)


def rwkv_block(params, cfg: ModelConfig, x, state: RWKVState, norms,
               *, use_kernel=False, n_real=None) -> Tuple[jnp.ndarray, RWKVState]:
    from repro.layers.core import rms_norm
    h, tm_shift, wkv = rwkv_time_mix(
        params["tm"], cfg, rms_norm(norms["n1"], x, cfg.rmsnorm_eps),
        state.tm_shift, state.wkv, use_kernel=use_kernel, n_real=n_real)
    x = x + h
    h, cm_shift = rwkv_channel_mix(
        params["cm"], rms_norm(norms["n2"], x, cfg.rmsnorm_eps), state.cm_shift,
        n_real=n_real)
    x = x + h
    return x, RWKVState(wkv, tm_shift.astype(state.tm_shift.dtype),
                        cm_shift.astype(state.cm_shift.dtype))
