"""Token-choice top-k MoE with capacity-based dispatch (GShard-style).

Dispatch materializes (E, C, d) expert inputs so the expert matmuls run as
grouped einsums with the expert axis shardable over the "model" mesh axis
(expert parallelism); XLA SPMD inserts the all-to-alls at the scatter/gather.
Shared experts (DeepSeek) are always-on dense FFNs added to the routed output.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.core import _act, init_linear, trunc_normal


def expert_ff(cfg: ModelConfig) -> int:
    return (cfg.moe.d_ff_expert or cfg.d_ff)


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, fe = cfg.d_model, expert_ff(cfg)
    dt = cfg.dtype()
    ks = jax.random.split(key, 5)
    glu = cfg.activation in ("swiglu", "geglu")
    std_in, std_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(fe)
    p = {
        "router": init_linear(ks[0], d, m.n_experts, dt),
        "up": trunc_normal(ks[1], (m.n_experts, d, fe), std_in, dt),
        "down": trunc_normal(ks[2], (m.n_experts, fe, d), std_out, dt),
    }
    if glu:
        p["gate"] = trunc_normal(ks[3], (m.n_experts, d, fe), std_in, dt)
    if m.n_shared_experts:
        fs = fe * m.n_shared_experts
        p["shared"] = {
            "up": init_linear(ks[4], d, fs, dt),
            "down": init_linear(jax.random.fold_in(ks[4], 1), fs, d, dt, std=1.0 / math.sqrt(fs)),
        }
        if glu:
            p["shared"]["gate"] = init_linear(jax.random.fold_in(ks[4], 2), d, fs, dt)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, -(-c // 8) * 8)   # round up to 8 for TPU lane alignment


def _route(params, cfg: ModelConfig, xt):
    """Router: probs, normalized top-k gates, and the Switch aux loss."""
    m = cfg.moe
    N = xt.shape[0]
    E, K = m.n_experts, m.top_k
    logits = xt @ params["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (N,E) f32
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (N,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)     # (N,K,E)
    ce = onehot.sum(axis=(0, 1)) / (N * K)
    aux = E * jnp.sum(me * ce)
    return gate_vals, expert_idx, onehot, aux


def _dispatch_ffn(cfg: ModelConfig, xt, gate_vals, expert_idx, onehot,
                  up_w, gate_w, down_w, C: int, e_lo):
    """Capacity dispatch + expert FFN + combine for experts [e_lo, e_lo+El).

    e_lo is a traced scalar under expert parallelism (shard-local expert
    offset) and 0 in the single-shard path. Tokens routed outside the local
    range are masked out of the dispatch; the caller psums partial outputs.
    """
    N, d = xt.shape
    K = gate_vals.shape[1]
    El = up_w.shape[0]
    local_slot = expert_idx - e_lo                                # (N,K)
    is_local = (local_slot >= 0) & (local_slot < El)
    oh_local = jnp.where(is_local[..., None],
                         jax.nn.one_hot(local_slot, El, dtype=jnp.float32), 0.0)
    flat = oh_local.reshape(N * K, El)
    pos = jnp.sum((jnp.cumsum(flat, axis=0) - flat) * flat, -1).astype(jnp.int32)
    keep = (pos < C) & is_local.reshape(N * K)
    eidx = jnp.clip(local_slot.reshape(N * K), 0, El - 1)
    dest = jnp.where(keep, eidx * C + pos, El * C)                # overflow slot

    xr = jnp.broadcast_to(xt[:, None, :], (N, K, d)).reshape(N * K, d)
    buf = jnp.zeros((El * C + 1, d), xt.dtype).at[dest].add(
        jnp.where(keep[:, None], xr, 0.0))
    xd = buf[: El * C].reshape(El, C, d)

    up = jnp.einsum("ecd,edf->ecf", xd, up_w.astype(xt.dtype))
    if gate_w is not None:
        g = jnp.einsum("ecd,edf->ecf", xd, gate_w.astype(xt.dtype))
        h = _act(cfg.activation, g) * up
    else:
        h = _act(cfg.activation, up)
    y_exp = jnp.einsum("ecf,efd->ecd", h, down_w.astype(xt.dtype))

    y_flat = y_exp.reshape(El * C, d)
    y_asn = jnp.where(keep[:, None], y_flat[jnp.clip(dest, 0, El * C - 1)], 0.0)
    w = (gate_vals.reshape(N * K) * keep).astype(xt.dtype)
    return (y_asn * w[:, None]).reshape(N, K, d).sum(axis=1)


def _shared_experts(params, cfg: ModelConfig, xt):
    sp = params["shared"]
    su = xt @ sp["up"]["w"].astype(xt.dtype)
    if "gate" in sp:
        sh = _act(cfg.activation, xt @ sp["gate"]["w"].astype(xt.dtype)) * su
    else:
        sh = _act(cfg.activation, su)
    return sh @ sp["down"]["w"].astype(xt.dtype)


def moe_apply(params, cfg: ModelConfig, x: jnp.ndarray, *,
              dropless: bool = False,
              shard_axes=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss).

    ``dropless=True`` sets capacity C = N (an expert can receive at most one
    assignment per token), making routing exact and batch-composition
    independent — used on the decode path where N = B is small. Training and
    prefill use capacity-factor dispatch (GShard semantics; capacity drops are
    batch-dependent, as in any capacity-routed system — see DESIGN.md).

    With ``shard_axes`` (distributed lowering) the routed experts run under
    **expert parallelism**: a shard_map over the "model" axis gives each shard
    its E/TP slice of expert weights; tokens are batch-sharded and
    model-replicated already, so each shard dispatches only to local experts
    and one psum combines the partial outputs. This keeps every dispatch
    buffer (the data-dependent scatter XLA cannot shard on its own) at 1/TP
    size — the fix for the 86 GB/device MoE temp (EXPERIMENTS.md §Perf).
    """
    m = cfg.moe
    B, T, d = x.shape
    N = B * T
    E, K = m.n_experts, m.top_k

    if shard_axes is None:
        xt = x.reshape(N, d)
        gate_vals, expert_idx, onehot, aux = _route(params, cfg, xt)
        C = N if dropless else moe_capacity(cfg, N)
        y = _dispatch_ffn(cfg, xt, gate_vals, expert_idx, onehot,
                          params["up"], params.get("gate"), params["down"],
                          C, 0)
        if m.n_shared_experts:
            y = y + _shared_experts(params, cfg, xt)
        return y.reshape(B, T, d), aux.astype(jnp.float32)

    # ---- expert-parallel path (shard_map over the model axis) ----
    from jax.sharding import PartitionSpec as P
    mesh = shard_axes["mesh"]
    tp = shard_axes["tp"]
    dp = shard_axes["dp"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_n = sizes[tp]
    assert E % tp_n == 0, (cfg.name, E, tp_n)
    El = E // tp_n
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    dp_n = 1
    for a in dp_axes:
        dp_n *= sizes.get(a, 1)
    if B % dp_n != 0:
        dp = None              # tiny global batch (long_500k): replicate B

    has_gate = "gate" in params

    def ep(x, router_w, up_w, down_w, *maybe_gate):
        Bl = x.shape[0]
        xt = x.reshape(Bl * T, d)
        n_local = xt.shape[0]                 # capacity is per-shard
        C = n_local if dropless else moe_capacity(cfg, n_local)
        gate_vals, expert_idx, onehot, aux = _route(
            {"router": {"w": router_w}}, cfg, xt)
        e_lo = jax.lax.axis_index(tp) * El
        gw = maybe_gate[0] if maybe_gate else None
        y = _dispatch_ffn(cfg, xt, gate_vals, expert_idx, onehot,
                          up_w, gw, down_w, C, e_lo)
        y = jax.lax.psum(y, tp)
        return y.reshape(Bl, T, d), aux

    args = [x, params["router"]["w"], params["up"], params["down"]]
    in_specs = [P(dp, None, None), P(None, None), P(tp, None, None),
                P(tp, None, None)]
    if has_gate:
        args.append(params["gate"])
        in_specs.append(P(tp, None, None))
    from repro.distributed.sharding import shard_map_compat
    y, aux = shard_map_compat(
        ep, mesh,
        tuple(in_specs),
        (P(dp, None, None), P()),
        check=False,
    )(*args)
    if m.n_shared_experts:
        y = y + _shared_experts(params, cfg, x.reshape(N, d)).reshape(B, T, d)
    return y, aux.astype(jnp.float32)
