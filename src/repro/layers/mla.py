"""Multi-head latent attention (DeepSeek-V2).

Train/prefill use the non-absorbed form (materialize per-head K/V from the
latent); decode uses **matrix absorption**: the cache holds only the rank-512
latent + the shared 64-dim RoPE key per token (576 elements/token), and the
query is absorbed through W_uk so attention runs directly in latent space.
This is the arch whose offloaded pages are smallest — the AQUA coalescing
insight (Fig. 3a) matters most here (see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.core import apply_rope, init_linear, init_rmsnorm, linear, rms_norm


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, S, kv_lora)   normalized latent
    k_rope: jnp.ndarray  # (B, S, rope_dim)  shared roped key


def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = cfg.dtype()
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_linear(ks[0], d, H * qd, dt),
        "wdkv": init_linear(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "wuk": init_linear(ks[2], m.kv_lora_rank, H * m.qk_nope_head_dim, dt),
        "wuv": init_linear(ks[3], m.kv_lora_rank, H * m.v_head_dim, dt),
        "wo": init_linear(ks[4], H * m.v_head_dim, d, dt),
    }
    return p


def _latents(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    c = linear(params["wdkv"], x)
    c_kv, k_rope = c[..., : m.kv_lora_rank], c[..., m.kv_lora_rank:]
    c_kv = rms_norm(params["kv_norm"], c_kv, cfg.rmsnorm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _queries(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    B, T, _ = x.shape
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = linear(params["wq"], x).reshape(B, T, cfg.n_heads, qd)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(params, cfg: ModelConfig, x, *, return_cache: bool = False):
    """Non-absorbed full-sequence causal MLA (train / prefill)."""
    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    positions = jnp.arange(T)[None, :]
    c_kv, k_rope = _latents(params, cfg, x, positions)
    q_nope, q_rope = _queries(params, cfg, x, positions)

    k_nope = linear(params["wuk"], c_kv).reshape(B, T, H, m.qk_nope_head_dim)
    v = linear(params["wuv"], c_kv).reshape(B, T, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, T, H, m.qk_rope_head_dim))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    mask = (jnp.arange(T)[None, :] <= jnp.arange(T)[:, None])[None, None]
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v)
    out = linear(params["wo"], ctx.reshape(B, T, -1))
    if return_cache:
        return out, (c_kv, k_rope)
    return out


def make_mla_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> MLACache:
    m = cfg.mla
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    return MLACache(jnp.zeros((batch, seq, m.kv_lora_rank), dt),
                    jnp.zeros((batch, seq, m.qk_rope_head_dim), dt))


def fill_mla_cache(cache: MLACache, c_kv, k_rope) -> MLACache:
    c = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_kv.astype(cache.c_kv.dtype), 0, 1)
    r = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, k_rope.astype(cache.k_rope.dtype), 0, 1)
    return MLACache(c, r)


def mla_decode(params, cfg: ModelConfig, x, cache: MLACache, pos
               ) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed single-token decode; cache is latent-space only."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos.reshape(-1)[:, None] if pos.ndim
                                 else pos[None, None], (B, 1))
    from repro.layers.core import select_update
    c_new, r_new = _latents(params, cfg, x, positions)
    c_kv = select_update(cache.c_kv, c_new[:, 0], positions[:, 0])
    k_rope = select_update(cache.k_rope, r_new[:, 0], positions[:, 0])

    q_nope, q_rope = _queries(params, cfg, x, positions)      # (B,1,H,*)
    # absorb: q_eff[h] = q_nope[h] @ W_uk[h]^T  -> latent space
    wuk = params["wuk"]["w"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bthd,chd->bthc", q_nope, wuk)          # (B,1,H,kv_lora)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bthc,bsc->bhts", q_eff, c_kv)
              + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope)) * scale
    S = c_kv.shape[1]
    mask = (jnp.arange(S)[None, :] <= positions[:, :1])[:, None, None, :]  # (B,1,1,S)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhts,bsc->bthc", probs, c_kv)        # (B,1,H,kv_lora)
    wuv = params["wuv"]["w"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)
    ctx = jnp.einsum("bthc,chd->bthd", ctx_lat, wuv)           # (B,1,H,v_dim)
    out = linear(params["wo"], ctx.reshape(B, 1, -1))
    return out, MLACache(c_kv, k_rope)
