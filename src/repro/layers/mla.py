"""Multi-head latent attention (DeepSeek-V2).

Train/prefill use the non-absorbed form (materialize per-head K/V from the
latent); decode uses **matrix absorption**: the cache holds only the rank-512
latent + the shared 64-dim RoPE key per token (576 elements/token), and the
query is absorbed through W_uk so attention runs directly in latent space.
This is the arch whose offloaded pages are smallest — the AQUA coalescing
insight (Fig. 3a) matters most here (see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.core import apply_rope, init_linear, init_rmsnorm, linear, rms_norm


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, S, kv_lora)   normalized latent
    k_rope: jnp.ndarray  # (B, S, rope_dim)  shared roped key


def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = cfg.dtype()
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_linear(ks[0], d, H * qd, dt),
        "wdkv": init_linear(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "wuk": init_linear(ks[2], m.kv_lora_rank, H * m.qk_nope_head_dim, dt),
        "wuv": init_linear(ks[3], m.kv_lora_rank, H * m.v_head_dim, dt),
        "wo": init_linear(ks[4], H * m.v_head_dim, d, dt),
    }
    return p


def _latents(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    c = linear(params["wdkv"], x)
    c_kv, k_rope = c[..., : m.kv_lora_rank], c[..., m.kv_lora_rank:]
    c_kv = rms_norm(params["kv_norm"], c_kv, cfg.rmsnorm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _queries(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    B, T, _ = x.shape
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = linear(params["wq"], x).reshape(B, T, cfg.n_heads, qd)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(params, cfg: ModelConfig, x, *, return_cache: bool = False):
    """Non-absorbed full-sequence causal MLA (train / prefill)."""
    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    positions = jnp.arange(T)[None, :]
    c_kv, k_rope = _latents(params, cfg, x, positions)
    q_nope, q_rope = _queries(params, cfg, x, positions)

    k_nope = linear(params["wuk"], c_kv).reshape(B, T, H, m.qk_nope_head_dim)
    v = linear(params["wuv"], c_kv).reshape(B, T, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, T, H, m.qk_rope_head_dim))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    mask = (jnp.arange(T)[None, :] <= jnp.arange(T)[:, None])[None, None]
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v)
    out = linear(params["wo"], ctx.reshape(B, T, -1))
    if return_cache:
        return out, (c_kv, k_rope)
    return out


def make_mla_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> MLACache:
    m = cfg.mla
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    return MLACache(jnp.zeros((batch, seq, m.kv_lora_rank), dt),
                    jnp.zeros((batch, seq, m.qk_rope_head_dim), dt))


def fill_mla_cache(cache: MLACache, c_kv, k_rope) -> MLACache:
    c = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_kv.astype(cache.c_kv.dtype), 0, 1)
    r = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, k_rope.astype(cache.k_rope.dtype), 0, 1)
    return MLACache(c, r)


# ---------------------------------------------------------------------------
# Paged latent cache (unified paged state runtime)
#
# The per-token MLA state is the rank-`kv_lora` latent plus the shared roped
# key — 576 native-dtype elements/token on V2. Both live fused in ONE token
# page plane: payload (page_tokens, kv_lora + rope_dim), mirroring the
# attention KV plane (`attention.write_chunk_pages` / `attention_decode_paged`)
# so preemption is the same page-table tier flip.
# ---------------------------------------------------------------------------
def latent_dim(cfg: ModelConfig) -> int:
    return cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim


def write_chunk_latent_pages(lat_pool, lat, block_table, offset, *,
                             page_tokens: int):
    """Chunked prefill writes latent pages in place: ``lat`` (1,Tc,C) lands at
    token row ``offset`` of the chunk's page WINDOW, gathered, row-updated and
    scattered back so rows written by earlier chunks survive a mid-page chunk
    boundary (the latent twin of ``attention.write_chunk_pages``).

    lat_pool: (P, page, C); block_table: (W,) int32 LOCAL slots of the window;
    offset: () int32, ``q_start % page_tokens``.
    """
    _, Tc, C = lat.shape
    W = block_table.shape[0]
    flat = lat_pool[block_table].reshape(W * page_tokens, C)
    flat = jax.lax.dynamic_update_slice_in_dim(
        flat, lat[0].astype(flat.dtype), offset, axis=0)
    return lat_pool.at[block_table].set(flat.reshape(W, page_tokens, C))


def _gather_latents(cfg: ModelConfig, lat_pool, block_table):
    """(..., pps) slots -> (B, pps*page, kv_lora) + (B, pps*page, rope_dim)."""
    m = cfg.mla
    pages = lat_pool[block_table]                    # (..., pps, page, C)
    allc = pages.reshape(pages.shape[:-3] + (-1, pages.shape[-1]))
    if allc.ndim == 2:
        allc = allc[None]
    return allc[..., : m.kv_lora_rank], allc[..., m.kv_lora_rank:]


def mla_prefill_chunk(params, cfg: ModelConfig, x, lat_pool, block_table,
                      q_start, *, read_pps: Optional[int] = None):
    """Chunked prefill MLA for ONE request (the paged twin of
    ``attention.attention_prefill_chunk``).

    x: (1,Tc,d) — one normed chunk at absolute positions ``q_start + [0,Tc)``;
    lat_pool: (P,page,C); block_table: (pps_pad,) int32 physical slots of the
    request's latent pages from position 0, dummy-padded. The chunk's latents
    are written into their page window first, then the chunk attends
    (non-absorbed, causal) to every latent written so far; ``read_pps`` bounds
    the sweep to pages a request can actually own, exactly as for KV pages.
    Any chunk split yields bit-identical outputs: every split reads the same
    pool-resident latents over the same ``read_pps``-page extent.
    """
    m = cfg.mla
    B, Tc, _ = x.shape
    assert B == 1, "chunked prefill is per-request"
    H = cfg.n_heads
    page = lat_pool.shape[1]
    q_start = jnp.asarray(q_start, jnp.int32).reshape(())
    positions = q_start + jnp.arange(Tc, dtype=jnp.int32)[None, :]
    c_kv, k_rope = _latents(params, cfg, x, positions)
    lat = jnp.concatenate([c_kv, k_rope], axis=-1)

    pps_win = Tc // page + (1 if Tc % page else 0) + 1
    win = jax.lax.dynamic_slice(block_table, (q_start // page,), (pps_win,))
    lat_pool = write_chunk_latent_pages(lat_pool, lat, win, q_start % page,
                                        page_tokens=page)

    c_all, r_all = _gather_latents(cfg, lat_pool, block_table[:read_pps])
    S = c_all.shape[1]
    k_nope = linear(params["wuk"], c_all).reshape(B, S, H, m.qk_nope_head_dim)
    v = linear(params["wuv"], c_all).reshape(B, S, H, m.v_head_dim)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(r_all[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    mask = (jnp.arange(S)[None, :] <= positions[0][:, None])[None, None]
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v)
    out = linear(params["wo"], ctx.reshape(B, Tc, -1))
    return out, lat_pool


def mla_mixed_paged(params, cfg: ModelConfig, x, lat_pool, block_table,
                    q_starts, n_reals, *, n_decode: int,
                    read_pps: Optional[int] = None):
    """Fused mixed-mode MLA: decode lanes and prefill chunk rows of a packed
    engine step against the latent pool, in one jitted region.

    x: (R, Tc, d) packed rows — rows ``[:n_decode]`` decode lanes (single
    real token at column 0, absolute position ``q_starts[r]``), the rest
    chunk rows (``n_reals[r]`` real tokens from ``q_starts[r]``; 0 marks a
    bucket-pad row). lat_pool: (P, page, C); block_table: (R, pps_pad).

    Per-plane row dispatch keeps each mode's exact math: decode rows run
    the ABSORBED single-token path of ``mla_decode_paged`` (tail-page
    append, latent-space scores), chunk rows the non-absorbed path of
    ``mla_prefill_chunk`` batched over rows (window write, materialized
    per-head K/V) — so every row is bit-identical to its per-request twin.
    """
    m = cfg.mla
    R, Tc, _ = x.shape
    H = cfg.n_heads
    page = lat_pool.shape[1]
    q_starts = jnp.asarray(q_starts, jnp.int32).reshape(-1)
    n_reals = jnp.asarray(n_reals, jnp.int32).reshape(-1)
    out_rows = []

    if n_decode:
        xd = x[:n_decode, :1]
        pos = q_starts[:n_decode]
        out_d, lat_pool = mla_decode_paged(params, cfg, xd, lat_pool,
                                           block_table[:n_decode, :read_pps],
                                           pos)
        if Tc > 1:
            out_d = jnp.concatenate(
                [out_d, jnp.zeros((n_decode, Tc - 1, out_d.shape[-1]),
                                  out_d.dtype)], axis=1)
        out_rows.append(out_d)

    if R > n_decode:
        xc = x[n_decode:]
        Rp = R - n_decode
        starts = q_starts[n_decode:]
        positions = starts[:, None] + jnp.arange(Tc, dtype=jnp.int32)[None, :]
        c_kv, k_rope = _latents(params, cfg, xc, positions)
        lat = jnp.concatenate([c_kv, k_rope], axis=-1)
        pps_win = Tc // page + (1 if Tc % page else 0) + 1
        for r in range(Rp):
            win = jax.lax.dynamic_slice(block_table[n_decode + r],
                                        (starts[r] // page,), (pps_win,))
            lat_pool = write_chunk_latent_pages(
                lat_pool, lat[r:r + 1], win, starts[r] % page,
                page_tokens=page)

        c_all, r_all = _gather_latents(cfg, lat_pool,
                                       block_table[n_decode:, :read_pps])
        S = c_all.shape[1]
        k_nope = linear(params["wuk"], c_all).reshape(Rp, S, H,
                                                      m.qk_nope_head_dim)
        v = linear(params["wuv"], c_all).reshape(Rp, S, H, m.v_head_dim)
        q_nope, q_rope = _queries(params, cfg, xc, positions)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(r_all[:, :, None, :],
                                      (Rp, S, H, m.qk_rope_head_dim))],
            axis=-1)
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
        mask = (jnp.arange(S)[None, None, :] <= positions[:, :, None])[:, None]
        scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(xc.dtype)
        ctx = jnp.einsum("bhts,bshd->bthd", probs, v)
        out_rows.append(linear(params["wo"], ctx.reshape(Rp, Tc, -1)))

    out = (out_rows[0] if len(out_rows) == 1
           else jnp.concatenate(out_rows, axis=0))
    return out, lat_pool


def mla_decode_paged(params, cfg: ModelConfig, x, lat_pool, block_table, pos):
    """Absorbed single-token decode reading/writing the paged latent pool.

    x: (B,1,d); lat_pool: (P,page,C); block_table: (B,pps) int32 physical
    LOCAL slots; pos: (B,). The new token's latent is appended into its tail
    page row in place, then absorbed attention runs over the gathered pages
    (masked past ``pos``), mirroring ``attention.attention_decode_paged``.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    page = lat_pool.shape[1]
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    positions = pos[:, None]                              # (B,1)
    c_new, r_new = _latents(params, cfg, x, positions)
    lat_new = jnp.concatenate([c_new, r_new], axis=-1)[:, 0]
    slot = jnp.take_along_axis(block_table, (pos // page)[:, None], axis=1)[:, 0]
    lat_pool = lat_pool.at[slot, pos % page].set(lat_new.astype(lat_pool.dtype))

    c_kv, k_rope = _gather_latents(cfg, lat_pool, block_table)   # (B,S,*)
    S = c_kv.shape[1]
    q_nope, q_rope = _queries(params, cfg, x, positions)
    wuk = params["wuk"]["w"].astype(x.dtype).reshape(m.kv_lora_rank, H,
                                                     m.qk_nope_head_dim)
    q_eff = jnp.einsum("bthd,chd->bthc", q_nope, wuk)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bthc,bsc->bhts", q_eff, c_kv)
              + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope)) * scale
    mask = (jnp.arange(S)[None, :] <= positions[:, :1])[:, None, None, :]
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhts,bsc->bthc", probs, c_kv)
    wuv = params["wuv"]["w"].astype(x.dtype).reshape(m.kv_lora_rank, H,
                                                     m.v_head_dim)
    ctx = jnp.einsum("bthc,chd->bthd", ctx_lat, wuv)
    out = linear(params["wo"], ctx.reshape(B, 1, -1))
    return out, lat_pool


def mla_decode(params, cfg: ModelConfig, x, cache: MLACache, pos
               ) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed single-token decode; cache is latent-space only."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos.reshape(-1)[:, None] if pos.ndim
                                 else pos[None, None], (B, 1))
    from repro.layers.core import select_update
    c_new, r_new = _latents(params, cfg, x, positions)
    c_kv = select_update(cache.c_kv, c_new[:, 0], positions[:, 0])
    k_rope = select_update(cache.k_rope, r_new[:, 0], positions[:, 0])

    q_nope, q_rope = _queries(params, cfg, x, positions)      # (B,1,H,*)
    # absorb: q_eff[h] = q_nope[h] @ W_uk[h]^T  -> latent space
    wuk = params["wuk"]["w"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bthd,chd->bthc", q_nope, wuk)          # (B,1,H,kv_lora)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bthc,bsc->bhts", q_eff, c_kv)
              + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope)) * scale
    S = c_kv.shape[1]
    mask = (jnp.arange(S)[None, :] <= positions[:, :1])[:, None, None, :]  # (B,1,1,S)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhts,bsc->bthc", probs, c_kv)        # (B,1,H,kv_lora)
    wuv = params["wuv"]["w"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)
    ctx = jnp.einsum("bthc,chd->bthd", ctx_lat, wuv)           # (B,1,H,v_dim)
    out = linear(params["wo"], ctx.reshape(B, 1, -1))
    return out, MLACache(c_kv, k_rope)
