"""GQA/MQA attention with full-sequence (train/prefill) and cached-decode paths.

Features: grouped KV heads, RoPE, qk-norm (gemma3), attention-logit softcap,
sliding-window masks, and ring-buffer KV caches for local (windowed) layers —
a local layer's cache is only ``window`` slots, which is what makes the
gemma3 long_500k cell feasible (40/48 layers hold 1024 slots instead of 512k).

``impl='xla'`` uses einsum attention (the dry-run path: cost_analysis then sees
the true FLOPs); ``impl='pallas'`` routes to the flash/paged kernels (TPU target,
validated in interpret mode in tests).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.core import apply_rope, init_linear, linear, qk_head_norm, trunc_normal


class KVCache(NamedTuple):
    """Per-layer KV cache. ``k``/``v``: (B, S_slots, n_kv, hd).

    For full-attention layers S_slots = max_seq; for windowed layers S_slots =
    window and the buffer is a ring indexed by ``pos % window``.
    """
    k: jnp.ndarray
    v: jnp.ndarray


def init_attention(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    dt = cfg.dtype()
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, dt, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def _project_qkv(params, cfg: ModelConfig, x, positions):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(params["wq"], x).reshape(B, T, cfg.n_heads, hd)
    k = linear(params["wk"], x).reshape(B, T, cfg.n_kv_heads, hd)
    v = linear(params["wv"], x).reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.use_qk_norm:
        q = qk_head_norm(params["q_norm"], q, cfg.rmsnorm_eps)
        k = qk_head_norm(params["k_norm"], k, cfg.rmsnorm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask) -> jnp.ndarray:
    """Grouped scaled-dot-product attention.

    q: (B,T,H,hd) k/v: (B,S,K,hd) mask: broadcastable to (B,1,1,T,S).
    """
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    q = q.reshape(B, T, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k) * scale
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = c * jnp.tanh(scores / c)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return ctx.reshape(B, T, H, hd)


def _causal_mask(q_pos, k_pos, window: int):
    """q_pos: (...,T) k_pos: (...,S) -> bool (...,1,1,T,S) mask."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    m &= k_pos[..., None, :] >= 0
    return m[..., None, None, :, :]


def attention_full(params, cfg: ModelConfig, x, *, window: int = 0,
                   pos_offset=0, return_kv: bool = False):
    """Training / prefill full-sequence causal attention."""
    B, T, _ = x.shape
    positions = pos_offset + jnp.arange(T)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions)
    mask = _causal_mask(positions, positions, window)
    ctx = _sdpa(cfg, q, k, v, mask)
    out = linear(params["wo"], ctx.reshape(B, T, -1))
    if return_kv:
        return out, (k, v)
    return out


def make_kv_cache(cfg: ModelConfig, batch: int, seq: int, window: int = 0,
                  dtype=None) -> KVCache:
    slots = min(window, seq) if window > 0 else seq
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    shape = (batch, slots, cfg.n_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def fill_kv_cache(cache: KVCache, k, v, window: int = 0) -> KVCache:
    """Write prefill K/V (B,T,Hkv,hd) into slots [0,T) (or the ring tail)."""
    T = k.shape[1]
    slots = cache.k.shape[1]
    if window > 0 and T > slots:
        k, v = k[:, T - slots:], v[:, T - slots:]
        # ring alignment: slot j holds position with pos % slots == j
        roll = (T - slots) % slots
        k = jnp.roll(k, roll, axis=1)
        v = jnp.roll(v, roll, axis=1)
        return KVCache(k.astype(cache.k.dtype), v.astype(cache.v.dtype))
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, 1)
    return KVCache(ck, cv)


def _write_slot(buf, new, slot):
    """buf: (B,S,K,hd), new: (B,1,K,hd), slot: (B,) int."""
    from repro.layers.core import select_update
    return select_update(buf, new[:, 0], slot)


def write_chunk_pages(kv_pool, k, v, block_table, offset, *, page_tokens: int):
    """Chunked prefill writes pages in place: K/V (1,Tc,Hkv,hd) of one chunk
    land at token row ``offset`` of the chunk's page WINDOW — the pages
    covering ``[q_start, q_start + Tc)``, gathered, row-updated and scattered
    back so rows written by earlier chunks survive a mid-page chunk boundary.

    kv_pool: (P, 2, K, page, hd); block_table: (W,) int32 LOCAL slots of the
    window (padding entries point at a resident dummy page whose content is
    never read unmasked); offset: () int32, ``q_start % page_tokens``.
    """
    _, Tc, K, hd = k.shape
    W = block_table.shape[0]
    pages = kv_pool[block_table]                            # (W,2,K,page,hd)
    flat = (pages.transpose(0, 3, 1, 2, 4)                  # token-major
            .reshape(W * page_tokens, 2, K, hd))
    new = jnp.stack([k[0], v[0]], axis=1)                   # (Tc,2,K,hd)
    flat = jax.lax.dynamic_update_slice_in_dim(
        flat, new.astype(flat.dtype), offset, axis=0)
    pages = (flat.reshape(W, page_tokens, 2, K, hd)
             .transpose(0, 2, 3, 1, 4))
    return kv_pool.at[block_table].set(pages)


def attention_prefill_chunk(params, cfg: ModelConfig, x, kv_pool, block_table,
                            q_start, *, read_pps: Optional[int] = None,
                            impl: str = "pallas"):
    """Chunked prefill attention for ONE request (full attention only).

    x: (1,Tc,d) — one chunk of the prompt at absolute positions
    ``q_start + [0, Tc)``; kv_pool: (P,2,K,page,hd); block_table: (pps_pad,)
    int32 physical slots of the request's pages from position 0, padded with
    a resident dummy; q_start: () int32 (traced — no retrace per position).

    The chunk's K/V is written into its page window first, then the chunk
    attends to every page written so far (causal within the chunk) through
    the query-block kernel; ``impl='xla'`` selects the jnp oracle.
    ``read_pps`` bounds the attention sweep to the pages a request can
    actually own: the table's extra tail entries exist only so the WRITE
    window's dynamic slice stays in bounds, and are always the dummy page —
    sweeping them would be pure masked waste in the serving hot spot.
    """
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.kernels.paged_attention.ref import \
        paged_prefill_attention_pool_ref
    B, Tc, _ = x.shape
    assert B == 1, "chunked prefill is per-request"
    page = kv_pool.shape[3]
    q_start = jnp.asarray(q_start, jnp.int32).reshape(())
    positions = q_start + jnp.arange(Tc, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    # the write window: ceil(Tc/page)+1 pages starting at the page holding
    # q_start (a mid-page chunk boundary touches one extra page)
    pps_win = Tc // page + (1 if Tc % page else 0) + 1
    page_idx = q_start // page
    win = jax.lax.dynamic_slice(block_table, (page_idx,), (pps_win,))
    kv_pool = write_chunk_pages(kv_pool, k_new, v_new, win, q_start % page,
                                page_tokens=page)
    bt = block_table[None, :read_pps]                       # (1, read_pps)
    if impl == "pallas":
        ctx = pa_ops.paged_prefill_attention_pool(q, kv_pool, bt,
                                                  q_start[None])
    else:
        ctx = paged_prefill_attention_pool_ref(q, kv_pool, bt, q_start[None])
    out = linear(params["wo"], ctx.reshape(B, Tc, -1))
    return out, kv_pool


def attention_mixed_paged(params, cfg: ModelConfig, x, kv_pool, block_table,
                          q_starts, n_reals, *, n_decode: int,
                          read_pps: Optional[int] = None,
                          impl: str = "pallas"):
    """Fused mixed-mode attention: decode lanes AND prefill chunk rows of a
    packed engine step against the pool, in ONE kernel launch.

    x: (R, Tc, d) packed rows — rows ``[:n_decode]`` are decode lanes (their
    single real token at column 0, absolute position ``q_starts[r]``), the
    rest prefill chunk rows (``n_reals[r]`` real tokens from absolute
    position ``q_starts[r]``; ``n_real == 0`` marks a bucket-pad row whose
    table points at the scratch page). kv_pool: (P,2,K,page,hd);
    block_table: (R, pps_pad) int32 physical LOCAL slots from position 0,
    scratch-padded.

    Writes exactly what the per-request paths write — decode lanes through
    the page-append writer, each chunk row through its read-modify-write
    page window — then attends every row in one
    ``paged_mixed_attention_pool`` launch. Row outputs are bit-identical to
    ``attention_decode_paged`` / ``attention_prefill_chunk`` on the same
    state: the kernel's page loop and accumulators are shared and a row's
    reduction never sees its neighbors.
    """
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.kernels.paged_attention.ref import (
        append_kv_ref, paged_mixed_attention_pool_ref)
    R, Tc, _ = x.shape
    page = kv_pool.shape[3]
    q_starts = jnp.asarray(q_starts, jnp.int32).reshape(-1)
    n_reals = jnp.asarray(n_reals, jnp.int32).reshape(-1)
    positions = q_starts[:, None] + jnp.arange(Tc, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    if n_decode:
        # decode lanes: one-token page append (idle lanes target scratch)
        pos = q_starts[:n_decode]
        slot = jnp.take_along_axis(block_table[:n_decode],
                                   (pos // page)[:, None], axis=1)[:, 0]
        off = pos % page
        kd, vd = k_new[:n_decode, 0], v_new[:n_decode, 0]
        if impl == "pallas":
            kv_pool = pa_ops.append_kv(kv_pool, kd, vd, slot, off)
        else:
            kv_pool = append_kv_ref(kv_pool, kd, vd, slot, off)
    pps_win = Tc // page + (1 if Tc % page else 0) + 1
    for r in range(n_decode, R):
        # chunk rows: the same page-window read-modify-write as the
        # per-request path (pad rows rewrite the scratch window — its
        # content is never read unmasked)
        win = jax.lax.dynamic_slice(block_table[r],
                                    (q_starts[r] // page,), (pps_win,))
        kv_pool = write_chunk_pages(kv_pool, k_new[r:r + 1], v_new[r:r + 1],
                                    win, q_starts[r] % page, page_tokens=page)

    is_decode = (jnp.arange(R, dtype=jnp.int32)
                 < n_decode).astype(jnp.int32)
    bt = block_table[:, :read_pps]
    if impl == "pallas":
        ctx = pa_ops.paged_mixed_attention_pool(q, kv_pool, bt, q_starts,
                                                n_reals, is_decode)
    else:
        ctx = paged_mixed_attention_pool_ref(q, kv_pool, bt, q_starts,
                                             n_reals, is_decode)
    out = linear(params["wo"], ctx.reshape(R, Tc, -1))
    return out, kv_pool


def attention_decode_paged(params, cfg: ModelConfig, x, kv_pool, block_table,
                           pos, *, impl: str = "pallas"):
    """One-token decode reading/writing the paged KV pool (full attention).

    x: (B,1,d); kv_pool: (P,2,K,page,hd) — the AquaTensor LOCAL pool;
    block_table: (B,pps) int32 physical page slots; pos: (B,) positions.
    The new token's K/V is appended in place via the page-append writer op
    and attention runs through kernels/paged_attention (interpret on CPU);
    ``impl='xla'`` selects the jnp oracles (dry-run / debugging).
    """
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.kernels.paged_attention.ref import (append_kv_ref,
                                                   paged_attention_pool_ref)
    B = x.shape[0]
    page = kv_pool.shape[3]
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    positions = pos[:, None]                                # (B,1)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    slot = jnp.take_along_axis(block_table, (pos // page)[:, None], axis=1)[:, 0]
    off = pos % page
    if impl == "pallas":
        kv_pool = pa_ops.append_kv(kv_pool, k_new[:, 0], v_new[:, 0], slot, off)
        ctx = pa_ops.paged_attention_pool(q[:, 0], kv_pool, block_table, pos + 1)
    else:
        kv_pool = append_kv_ref(kv_pool, k_new[:, 0], v_new[:, 0], slot, off)
        ctx = paged_attention_pool_ref(q[:, 0], kv_pool, block_table, pos + 1)
    out = linear(params["wo"], ctx.reshape(B, 1, -1))
    return out, kv_pool


def attention_decode(params, cfg: ModelConfig, x, cache: KVCache, pos,
                     *, window: int = 0) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode. x: (B,1,d); pos: scalar or (B,) current position."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos.reshape(-1)[:, None] if pos.ndim
                                 else pos[None, None], (B, 1))
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    slots = cache.k.shape[1]
    is_ring = window > 0 and slots <= window
    slot = positions[:, 0] % slots if is_ring else positions[:, 0]
    ck = _write_slot(cache.k, k_new, slot)
    cv = _write_slot(cache.v, v_new, slot)

    j = jnp.arange(slots)
    p = positions[:, :1]                                 # (B,1)
    if is_ring:
        # ring buffer: slot j holds k_pos = p - ((p - j) mod slots)
        k_pos = p - ((p - j[None, :]) % slots)           # (B,S)
    else:
        k_pos = jnp.broadcast_to(j[None, :], (B, slots))
    mask = _causal_mask(p, k_pos, window)                # (B,1,1,1,S)
    ctx = _sdpa(cfg, q, ck, cv, mask)
    out = linear(params["wo"], ctx.reshape(B, 1, -1))
    return out, KVCache(ck, cv)
