"""Mamba-1 selective SSM block (used by the Jamba hybrid).

Training/prefill use a chunked associative scan (memory O(B*chunk*di*ds) per
chunk instead of O(B*T*di*ds)); decode is a single recurrence step over the
per-request state — the "dynamic context" AQUA pages for hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.core import init_linear, linear, trunc_normal

MAMBA_CHUNK = 128


class MambaState(NamedTuple):
    ssm: jnp.ndarray    # (B, di, ds) f32
    conv: jnp.ndarray   # (B, d_conv-1, di) last inputs for the causal conv


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.mamba_expand * cfg.d_model
    dtr = s.mamba_dt_rank or cfg.d_model // 16
    return di, s.mamba_d_state, s.mamba_d_conv, dtr


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    di, ds, dc, _ = _dims(cfg)
    return MambaState(jnp.zeros((batch, di, ds), jnp.float32),
                      jnp.zeros((batch, dc - 1, di), dtype))


def init_mamba_layer(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, ds, dc, dtr = _dims(cfg)
    dt = cfg.dtype()
    ks = jax.random.split(key, 5)
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, dt),
        "conv_w": trunc_normal(ks[1], (dc, di), 0.5, dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": init_linear(ks[2], di, dtr + 2 * ds, dt),
        "dt_proj": init_linear(ks[3], dtr, di, dt),
        "dt_bias": jnp.full((di,), -4.6, dt),   # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ks[4], di, d, dt),
    }


def _ssm_inputs(p, cfg, xc):
    """xc: (B,T,di) post-conv activations -> dt, B, C."""
    _, ds, _, dtr = _dims(cfg)
    bcd = linear(p["x_proj"], xc)
    dt_in, Bm, Cm = jnp.split(bcd, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt_in).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))       # (B,T,di)
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _chunked_ssm_scan(dt, Bm, Cm, x, A, h0):
    """Selective scan, chunked. dt,x: (B,T,di); Bm,Cm: (B,T,ds); A: (di,ds).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t

    The discretized transition tensors a,b (B,ck,di,ds) are computed *inside*
    the chunk body — materializing them over the full T is O(T*di*ds) memory
    (265 GB/device on the jamba train cell; EXPERIMENTS.md §Perf) while the
    per-chunk working set is O(ck*di*ds).
    """
    B, T, di = x.shape
    ds = Bm.shape[-1]
    nchunk = T // MAMBA_CHUNK if T % MAMBA_CHUNK == 0 and T >= MAMBA_CHUNK else 1
    ck = T // nchunk

    def chunk_step(h, inp):
        dt_c, B_c, C_c, x_c = inp                                 # (B,ck,*)
        a_c = jnp.exp(dt_c[..., None] * A[None, None])            # (B,ck,di,ds)
        b_c = (dt_c * x_c.astype(jnp.float32))[..., None] * B_c[:, :, None, :]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2
        aa, bb = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        h_all = aa * h[:, None] + bb                              # (B,ck,di,ds)
        y = jnp.einsum("btds,bts->btd", h_all, C_c)
        return h_all[:, -1], y

    def chunked(v):
        return jnp.moveaxis(v.reshape((B, nchunk, ck) + v.shape[2:]), 1, 0)

    h, ys = jax.lax.scan(chunk_step, h0,
                         (chunked(dt), chunked(Bm), chunked(Cm), chunked(x)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, di)
    return y, h


def _causal_conv(p, x, conv_prev, n_real=None):
    """Depthwise causal conv over time. x: (B,T,di); conv_prev: (B,dc-1,di).

    ``n_real`` (traced scalar or per-lane ``(B,)`` vector, default T) marks
    the last REAL row of a bucket-padded chunk: the returned conv tail is
    the window of the last ``dc-1`` inputs *ending at* that row, so padding
    rows never enter the carried state. ``xp`` row ``j`` holds input
    ``j-(dc-1)``, hence the tail window for ``n_real`` real tokens starts at
    ``xp`` row ``n_real``. The vector form (the fused packed step, one
    n_real per lane) gathers each lane's window; the scalar form keeps the
    original dynamic slice bit-exactly.
    """
    dc = p["conv_w"].shape[0]
    xp = jnp.concatenate([conv_prev.astype(x.dtype), x], axis=1)  # (B,T+dc-1,di)
    w = p["conv_w"].astype(x.dtype)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(dc))
    if n_real is not None and jnp.ndim(n_real) > 0:
        idx = (jnp.asarray(n_real, jnp.int32)[:, None]
               + jnp.arange(dc - 1, dtype=jnp.int32)[None, :])   # (B, dc-1)
        tail = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    else:
        tail_start = x.shape[1] if n_real is None else n_real
        tail = jax.lax.dynamic_slice_in_dim(xp, tail_start, dc - 1, axis=1)
    return out + p["conv_b"].astype(x.dtype), tail


def mamba_forward(p, cfg: ModelConfig, x, state: MambaState, shard_axes=None,
                  n_real=None) -> Tuple[jnp.ndarray, MambaState]:
    """Full-sequence forward. x: (B,T,d).

    ``n_real`` (traced scalar, or a per-lane ``(B,)`` vector in the fused
    packed step) supports bucket-padded chunked prefill: rows ``>= n_real``
    are padding whose dt is zeroed, making their transition the identity
    (``a = exp(0) = 1``, ``b = 0``) — the carried SSM state after the chunk
    equals the state after the last real token, bit-exactly, and the conv
    tail window ends at the last real row.
    """
    di, ds, dc, dtr = _dims(cfg)
    xz = linear(p["in_proj"], x)
    if shard_axes:
        # keep the expanded inner dim (di = 2*d_model) TP-sharded through the
        # conv/scan chain — the scan working set is O(ck*di*ds) per device
        from repro.models.losses import constrain
        xz = constrain(xz, (shard_axes["dp"], None, shard_axes["tp"]))
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = _causal_conv(p, x_in, state.conv, n_real=n_real)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_inputs(p, cfg, xc)
    if n_real is not None:
        nr = jnp.asarray(n_real, jnp.int32).reshape(-1, 1)     # (1|B, 1)
        mask = jnp.arange(x.shape[1])[None, :] < nr
        dt = dt * mask[:, :, None]
    A = -jnp.exp(p["A_log"])
    y, h = _chunked_ssm_scan(dt, Bm, Cm, xc, A, state.ssm)
    y = (y + p["D"][None, None] * xc.astype(jnp.float32)).astype(x.dtype)
    out = linear(p["out_proj"], y * jax.nn.silu(z))
    return out, MambaState(h, conv_tail.astype(state.conv.dtype))


def mamba_decode(p, cfg: ModelConfig, x, state: MambaState
                 ) -> Tuple[jnp.ndarray, MambaState]:
    """Single-token step. x: (B,1,d)."""
    di, ds, dc, dtr = _dims(cfg)
    xz = linear(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = _causal_conv(p, x_in, state.conv)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_inputs(p, cfg, xc)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A[None])                       # (B,di,ds)
    b = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = a * state.ssm + b
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0])[:, None]
    y = (y + p["D"][None, None] * xc.astype(jnp.float32)).astype(x.dtype)
    out = linear(p["out_proj"], y * jax.nn.silu(z))
    return out, MambaState(h, conv_tail.astype(state.conv.dtype))
