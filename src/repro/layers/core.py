"""Core layers: norms, rotary embeddings, linear/MLP, embeddings.

Pure-functional: ``init_*`` build param pytrees (nested dicts of jnp arrays),
``apply``-style functions are stateless. Everything is scan-stackable (params may
carry a leading layer-group axis).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def trunc_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def select_update(buf: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray):
    """Write ``new[b]`` into ``buf[b, slot[b]]`` via a one-hot select.

    Equivalent to ``buf.at[arange(B), slot].set(new)`` but avoids XLA's bf16
    scatter lowering, which round-trips the ENTIRE buffer through f32 — on a
    32k-slot stacked KV cache that was 26 GB of phantom traffic per decode
    step (HC3, EXPERIMENTS.md §Perf). The select fuses into a masked copy.
    """
    B, S = buf.shape[:2]
    oh = jnp.arange(S, dtype=slot.dtype)[None, :] == slot[:, None]   # (B,S)
    oh = oh.reshape(oh.shape + (1,) * (buf.ndim - 2))
    return jnp.where(oh, new[:, None].astype(buf.dtype), buf)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}       # gemma-style (1 + w) param


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def qk_head_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    """RMS norm over the head_dim of (..., H, hd) tensors (gemma3 qk-norm)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / MLP
# ---------------------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False,
                std: Optional[float] = None) -> dict:
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": trunc_normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def _act(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu" or name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.dtype()
    k1, k2, k3 = jax.random.split(key, 3)
    glu = cfg.activation in ("swiglu", "geglu")
    p = {"up": init_linear(k1, d, f, dt),
         "down": init_linear(k2, f, d, dt, std=1.0 / math.sqrt(f))}
    if glu:
        p["gate"] = init_linear(k3, d, f, dt)
    return p


def mlp(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    up = linear(params["up"], x)
    if "gate" in params:
        h = _act(cfg.activation, linear(params["gate"], x)) * up
    else:
        h = _act(cfg.activation, up)
    return linear(params["down"], h)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------
def init_embedding(key, cfg: ModelConfig) -> dict:
    dt = cfg.dtype()
    p = {"tok": trunc_normal(key, (cfg.vocab_size, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["head"] = trunc_normal(jax.random.fold_in(key, 1),
                                 (cfg.d_model, cfg.vocab_size),
                                 1.0 / math.sqrt(cfg.d_model), dt)
    return p


def embed(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["tok"].astype(cfg.compute_dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ params["tok"].astype(x.dtype).T
    else:
        logits = x @ params["head"].astype(x.dtype)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
