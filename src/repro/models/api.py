"""Uniform model API across families + input construction for every
(arch × shape) cell.

``make_inputs`` returns ShapeDtypeStructs (dry-run safe); ``instantiate`` turns
them into concrete deterministic arrays for tests/examples.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ENCDEC, ModelConfig, ShapeConfig
from repro.models import encdec, lm


def model_module(cfg: ModelConfig):
    return encdec if cfg.family == ENCDEC else lm


def init_params(key, cfg: ModelConfig):
    return model_module(cfg).init_params(key, cfg)


def param_specs(cfg: ModelConfig):
    return model_module(cfg).param_specs(cfg)


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat: bool = False,
            shard_axes=None):
    return model_module(cfg).loss_fn(params, cfg, batch, remat=remat,
                                     shard_axes=shard_axes)


def init_decode_state(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    return model_module(cfg).init_decode_state(cfg, batch, seq, dtype)


def decode_state_specs(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    return model_module(cfg).decode_state_specs(cfg, batch, seq, dtype)


def prefill(params, cfg: ModelConfig, tokens, cache, **extras):
    return model_module(cfg).prefill(params, cfg, tokens, cache, **extras)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, shard_axes=None):
    return model_module(cfg).decode_step(params, cfg, cache, tokens, pos,
                                         shard_axes=shard_axes)


# --- unified paged serving runtime (ALL dynamic context on pages) ----------
def supports_paged(cfg: ModelConfig) -> bool:
    return cfg.family != ENCDEC and lm.supports_paged(cfg)


def paged_layout(cfg: ModelConfig) -> dict:
    return lm.paged_layout(cfg)


def prefill_chunk_paged(params, cfg: ModelConfig, tokens, pools,
                        block_tables, q_start, last_index, *,
                        prefix_embeds=None, read_pps=None,
                        impl: str = "pallas"):
    """One bucket-padded prompt chunk -> (logits (1,V) of ``last_index``,
    pools). Jit'd; trace count is bounded by the shape-bucket ladder."""
    return lm.prefill_chunk_paged_jit(params, cfg, tokens, pools,
                                      block_tables, q_start, last_index,
                                      prefix_embeds=prefix_embeds,
                                      read_pps=read_pps, impl=impl)


def decode_step_paged(params, cfg: ModelConfig, pools, block_tables,
                      tokens, pos, *, impl: str = "pallas"):
    return lm.decode_step_paged_jit(params, cfg, pools, block_tables,
                                    tokens, pos, impl=impl)


def serve_step_paged(params, cfg: ModelConfig, tokens, pools, block_tables,
                     q_starts, n_reals, *, n_decode: int, prefix_embeds=None,
                     read_pps=None, impl: str = "pallas"):
    """One FUSED engine step: every decode lane and every request's prompt
    chunk packed into a (R, Tc) row batch served by a single jitted call
    (one attention launch per layer) -> (logits (R,V), pools). Row logits
    are bit-identical to the per-request ``decode_step_paged`` /
    ``prefill_chunk_paged`` calls the packed rows replace. Jit'd; the trace
    count is bounded by the (rows x tokens) bucket ladder."""
    return lm.serve_step_paged_jit(params, cfg, tokens, pools, block_tables,
                                   q_starts, n_reals, n_decode=n_decode,
                                   prefix_embeds=prefix_embeds,
                                   read_pps=read_pps, impl=impl)


# ---------------------------------------------------------------------------
# Inputs per (arch, shape)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_inputs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: int = 0) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {tokens, prefix_embeds?/enc_embeds?}
    prefill: {tokens, cache, prefix_embeds?/enc_embeds?}
    decode:  {tokens (B,), pos (B,), cache}
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    tok = jnp.int32
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        t_text = S - cfg.n_prefix_embeds
        out["tokens"] = _sds((B, t_text), tok)
        if cfg.n_prefix_embeds:
            out["prefix_embeds"] = _sds((B, cfg.n_prefix_embeds, cfg.d_model), dt)
        if cfg.family == ENCDEC:
            out["enc_embeds"] = _sds((B, cfg.encdec.encoder_seq_len, cfg.d_model), dt)
    elif shape.kind == "prefill":
        t_text = S - cfg.n_prefix_embeds
        out["tokens"] = _sds((B, t_text), tok)
        if cfg.n_prefix_embeds:
            out["prefix_embeds"] = _sds((B, cfg.n_prefix_embeds, cfg.d_model), dt)
        if cfg.family == ENCDEC:
            out["enc_embeds"] = _sds((B, cfg.encdec.encoder_seq_len, cfg.d_model), dt)
        out["cache"] = decode_state_specs(cfg, B, S)
    elif shape.kind == "decode":
        out["tokens"] = _sds((B,), tok)
        out["pos"] = _sds((B,), tok)
        out["cache"] = decode_state_specs(cfg, B, S)
    else:
        raise ValueError(shape.kind)
    return out


def instantiate(specs, seed: int = 0):
    """Deterministic concrete arrays matching a spec pytree (tests/examples)."""
    leaves, treedef = jax.tree.flatten(specs)
    rng = np.random.default_rng(seed)
    out = []
    for l in leaves:
        if jnp.issubdtype(l.dtype, jnp.integer):
            out.append(jnp.asarray(rng.integers(0, 100, l.shape), l.dtype))
        else:
            out.append(jnp.asarray(rng.standard_normal(l.shape) * 0.02, l.dtype))
    return jax.tree.unflatten(treedef, out)
