"""Sharding-aware cross-entropy.

At 100k-262k vocab, the (B, T, V) logits chain dominates training memory if
the SPMD partitioner loses the vocab sharding: ``take_along_axis`` over a
model-sharded vocab dim forces an all-gather of the full logits, after which
every downstream op is replicated (observed: 259 GB/device temp on the
dbrx-132b train cell before this fix; 5.9 GB after — EXPERIMENTS.md §Perf).

Fix: constrain logits to P(dp, None, "model") and compute
    nll = logsumexp(logits) - <logits, one_hot(target)>
both of which reduce over the *sharded* vocab axis with a psum instead of
gathering it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def constrain(x, spec: Optional[tuple]):
    """with_sharding_constraint if specs are provided (dry-run / production);
    identity in unsharded CPU tests."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shifted_xent(logits, tokens, shard_axes: Optional[dict] = None):
    """Next-token CE. logits: (B, T, V) aligned with tokens (B, T)."""
    if shard_axes:
        logits = constrain(logits, (shard_axes["dp"], None, shard_axes["tp"]))
    lf = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(lf, axis=-1)            # psum over vocab
    oh = jax.nn.one_hot(tgt, logits.shape[-1], dtype=lf.dtype)
    if shard_axes:
        oh = constrain(oh, (shard_axes["dp"], None, shard_axes["tp"]))
    tl = jnp.einsum("btv,btv->bt", lf, oh)                    # psum over vocab
    return (lse - tl).mean()
