"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the brief: callers provide precomputed
frame embeddings ``enc_embeds`` (B, T_enc, d_model) via ``input_specs()``.
Decoder layers: causal self-attention (cached) + cross-attention over the
encoder output (cross-KV computed once at prefill = an ideal AQUA cold page)
+ MLP. Adaptation (DESIGN.md): RMSNorm + RoPE replace LayerNorm + learned
positions to share the substrate.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers import attention as attn
from repro.layers.core import (embed, init_embedding, init_linear, init_mlp,
                               init_rmsnorm, linear, mlp, rms_norm, unembed)


def _init_cross(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = cfg.dtype()
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, dt),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, dt),
    }


def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dt = cfg.dtype()
    return {"n1": init_rmsnorm(cfg.d_model, dt),
            "attn": attn.init_attention(k1, cfg),
            "n2": init_rmsnorm(cfg.d_model, dt),
            "ffn": init_mlp(k2, cfg)}


def _init_dec_layer(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.dtype()
    return {"n1": init_rmsnorm(cfg.d_model, dt),
            "self": attn.init_attention(k1, cfg),
            "n2": init_rmsnorm(cfg.d_model, dt),
            "cross": _init_cross(k2, cfg),
            "n3": init_rmsnorm(cfg.d_model, dt),
            "ffn": init_mlp(k3, cfg)}


def init_params(key, cfg: ModelConfig) -> dict:
    ke, k1, k2 = jax.random.split(key, 3)
    E = cfg.encdec.n_encoder_layers
    L = cfg.n_layers
    return {
        "embed": init_embedding(ke, cfg),
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg))(jax.random.split(k1, E)),
        "enc_norm": init_rmsnorm(cfg.d_model, cfg.dtype()),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg))(jax.random.split(k2, L)),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.dtype()),
    }


def param_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def _bidir_attention(p, cfg: ModelConfig, x):
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :]
    q, k, v = attn._project_qkv(p, cfg, x, positions)
    mask = jnp.ones((1, 1, 1, T, T), bool)
    ctx = attn._sdpa(cfg, q, k, v, mask)
    return linear(p["wo"], ctx.reshape(B, T, -1))


def encode(params, cfg: ModelConfig, enc_embeds):
    def body(x, lp):
        h = _bidir_attention(lp["attn"], cfg, rms_norm(lp["n1"], x, cfg.rmsnorm_eps))
        x = x + h
        x = x + mlp(lp["ffn"], cfg, rms_norm(lp["n2"], x, cfg.rmsnorm_eps))
        return x, None
    x, _ = jax.lax.scan(body, enc_embeds.astype(cfg.compute_dtype),
                        params["enc_blocks"])
    return rms_norm(params["enc_norm"], x, cfg.rmsnorm_eps)


def _cross_kv(p, cfg: ModelConfig, enc_out):
    B, Te, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = linear(p["wk"], enc_out).reshape(B, Te, cfg.n_kv_heads, hd)
    v = linear(p["wv"], enc_out).reshape(B, Te, cfg.n_kv_heads, hd)
    return k, v


def _cross_attend(p, cfg: ModelConfig, x, ck, cv):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(B, T, cfg.n_heads, hd)
    mask = jnp.ones((1, 1, 1, T, ck.shape[1]), bool)
    ctx = attn._sdpa(cfg, q, ck, cv, mask)
    return linear(p["wo"], ctx.reshape(B, T, -1))


def _dec_layer(lp, cfg: ModelConfig, x, ck, cv, *, cache=None, pos=None,
               return_kv=False):
    h_in = rms_norm(lp["n1"], x, cfg.rmsnorm_eps)
    if cache is not None:
        h, new_cache = attn.attention_decode(lp["self"], cfg, h_in,
                                             attn.KVCache(*cache), pos)
    elif return_kv:
        h, kv = attn.attention_full(lp["self"], cfg, h_in, return_kv=True)
        new_cache = kv
    else:
        h = attn.attention_full(lp["self"], cfg, h_in)
        new_cache = None
    x = x + h
    x = x + _cross_attend(lp["cross"], cfg, rms_norm(lp["n2"], x, cfg.rmsnorm_eps), ck, cv)
    x = x + mlp(lp["ffn"], cfg, rms_norm(lp["n3"], x, cfg.rmsnorm_eps))
    return x, new_cache


def forward(params, cfg: ModelConfig, tokens, enc_embeds, *, remat=False,
            shard_axes=None):
    """Training: (dec tokens (B,T), enc_embeds (B,Te,d)) -> logits (B,T,V)."""
    enc_out = encode(params, cfg, enc_embeds)
    x = embed(params["embed"], cfg, tokens)

    def body(x, lp):
        ck, cv = _cross_kv(lp["cross"], cfg, enc_out)
        x, _ = _dec_layer(lp, cfg, x, ck, cv)
        return x, None
    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    return unembed(params["embed"], cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat=False,
            shard_axes=None):
    from repro.models.losses import shifted_xent
    logits, _ = forward(params, cfg, batch["tokens"], batch["enc_embeds"],
                        remat=remat, shard_axes=shard_axes)
    return shifted_xent(logits, batch["tokens"], shard_axes)


def init_decode_state(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    Te = cfg.encdec.encoder_seq_len
    self_kv = attn.make_kv_cache(cfg, batch, seq, 0, dt)
    cross = attn.KVCache(jnp.zeros((batch, Te, cfg.n_kv_heads, hd), dt),
                         jnp.zeros((batch, Te, cfg.n_kv_heads, hd), dt))
    one = {"self": self_kv, "cross": cross}
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), one)


def decode_state_specs(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    return jax.eval_shape(functools.partial(init_decode_state, cfg, batch, seq, dtype))


def prefill(params, cfg: ModelConfig, tokens, cache, *, enc_embeds,
            shard_axes=None):
    enc_out = encode(params, cfg, enc_embeds)
    x = embed(params["embed"], cfg, tokens)

    def body(x, xs):
        lp, c = xs
        ck, cv = _cross_kv(lp["cross"], cfg, enc_out)
        x, kv = _dec_layer(lp, cfg, x, ck, cv, return_kv=True)
        self_c = attn.fill_kv_cache(attn.KVCache(*c["self"]), kv[0], kv[1])
        return x, {"self": self_c, "cross": attn.KVCache(ck.astype(c["cross"][0].dtype),
                                                         cv.astype(c["cross"][1].dtype))}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    return unembed(params["embed"], cfg, x[:, -1:])[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, shard_axes=None):
    x = embed(params["embed"], cfg, tokens[:, None])

    def body(x, xs):
        lp, c = xs
        ck, cv = c["cross"]
        x, self_c = _dec_layer(lp, cfg, x, ck.astype(x.dtype), cv.astype(x.dtype),
                               cache=c["self"], pos=pos)
        return x, {"self": self_c, "cross": attn.KVCache(ck, cv)}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = rms_norm(params["final_norm"], x, cfg.rmsnorm_eps)
    return unembed(params["embed"], cfg, x)[:, 0], new_cache
